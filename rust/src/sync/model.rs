//! Instrumented, deterministically-schedulable sync primitives
//! (compiled only under `--cfg ggcheck`; see [`crate::sync`]).
//!
//! Every type here is *dual-flavor*: at construction it asks
//! [`rt::active`] whether the calling thread is inside a
//! [`crate::checker`] execution. Outside one it wraps the `std`
//! primitive untouched (so a ggcheck build still runs the ordinary
//! test suite with real concurrency); inside one it routes every
//! blocking edge through the checker's cooperative scheduler:
//!
//! * `Mutex::lock` — yield, then try-acquire, else park on the mutex.
//! * `Condvar::wait` — release-and-park *atomically* (no yield point
//!   between the two, so a concurrent notify cannot be missed), then
//!   re-lock. `notify_*` wakes **all** waiters — a sound superset of
//!   `std`'s spurious-wakeup licence.
//! * atomics — one yield before each operation; every ordering is
//!   strengthened to `SeqCst` (the model checks interleavings, not
//!   weak-memory reorderings).
//! * channels — a `VecDeque` behind a host mutex with one checker
//!   wait-resource per channel; `recv_timeout` **times out
//!   immediately** when the queue is empty (the model has no clock —
//!   a timeout is just one more schedulable outcome).
//! * `thread::sleep` — a plain yield (again: no clock).
//!
//! Cancellation rule: when the scheduler condemns a schedule it
//! unwinds every model thread, and `Drop` impls may re-enter these
//! primitives mid-unwind. All blocking loops therefore bail out via
//! [`rt::cancelled`] instead of parking, and all release/wake paths
//! never yield.

use crate::checker::rt;
use std::cell::UnsafeCell;
use std::collections::VecDeque;
use std::fmt;
use std::ops::{Deref, DerefMut};
use std::sync::{
    Arc, LockResult, Mutex as StdMutex, MutexGuard as StdMutexGuard, PoisonError,
};
use std::time::Duration;

fn host_lock<T>(m: &StdMutex<T>) -> StdMutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

// ---------------------------------------------------------------- Mutex

enum MutexFlavor<T> {
    Std(StdMutex<T>),
    Model { id: usize, cell: UnsafeCell<T> },
}

/// Dual-flavor mutex with the `std::sync::Mutex` lock/poison API.
pub struct Mutex<T> {
    inner: MutexFlavor<T>,
}

// SAFETY: the Std flavor inherits std's Send/Sync. The Model flavor's
// UnsafeCell is only dereferenced between a successful
// rt::mutex_try_acquire and the matching rt::mutex_release, and the
// checker scheduler guarantees at most one holder at a time (single
// runnable thread + the acquire/release protocol), so cross-thread
// shared access to the cell is mutually exclusive. `T: Send` is
// required because the protected value is accessed from whichever
// thread holds the lock.
unsafe impl<T: Send> Send for Mutex<T> {}
// SAFETY: as above — `&Mutex<T>` only exposes `T` through the
// scheduler-serialised lock protocol.
unsafe impl<T: Send> Sync for Mutex<T> {}

impl<T> Mutex<T> {
    pub fn new(value: T) -> Mutex<T> {
        if rt::active() {
            Mutex { inner: MutexFlavor::Model { id: rt::new_mutex(), cell: UnsafeCell::new(value) } }
        } else {
            Mutex { inner: MutexFlavor::Std(StdMutex::new(value)) }
        }
    }

    pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
        match &self.inner {
            MutexFlavor::Std(m) => match m.lock() {
                Ok(g) => Ok(MutexGuard { mx: self, std: Some(g), released: false }),
                Err(poison) => Err(PoisonError::new(MutexGuard {
                    mx: self,
                    std: Some(poison.into_inner()),
                    released: false,
                })),
            },
            MutexFlavor::Model { id, .. } => loop {
                rt::yield_point();
                if rt::mutex_try_acquire(*id) {
                    return Ok(MutexGuard { mx: self, std: None, released: false });
                }
                rt::block_on_mutex(*id);
            },
        }
    }
}

impl<T: fmt::Debug> fmt::Debug for Mutex<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Mutex").finish_non_exhaustive()
    }
}

/// Guard for [`Mutex`]. The model flavor releases through
/// [`rt::mutex_release`] on drop (never yielding, so dropping a guard
/// during unwind is safe).
pub struct MutexGuard<'a, T> {
    mx: &'a Mutex<T>,
    std: Option<StdMutexGuard<'a, T>>,
    /// Set by `Condvar::wait`, which hands the release to the checker
    /// itself so the release+park pair stays atomic.
    released: bool,
}

impl<'a, T> Deref for MutexGuard<'a, T> {
    type Target = T;
    fn deref(&self) -> &T {
        match (&self.std, &self.mx.inner) {
            (Some(g), _) => g,
            // SAFETY: this guard was created by a successful model
            // acquire and not yet released; the scheduler serialises
            // holders, so no aliasing &mut exists.
            (None, MutexFlavor::Model { cell, .. }) => unsafe { &*cell.get() },
            (None, MutexFlavor::Std(_)) => unreachable!("std guard lost its inner guard"),
        }
    }
}

impl<'a, T> DerefMut for MutexGuard<'a, T> {
    fn deref_mut(&mut self) -> &mut T {
        match (&mut self.std, &self.mx.inner) {
            (Some(g), _) => g,
            // SAFETY: exclusive model lock held (see Deref); &mut self
            // additionally prevents aliasing through this guard.
            (None, MutexFlavor::Model { cell, .. }) => unsafe { &mut *cell.get() },
            (None, MutexFlavor::Std(_)) => unreachable!("std guard lost its inner guard"),
        }
    }
}

impl<'a, T> Drop for MutexGuard<'a, T> {
    fn drop(&mut self) {
        if self.released || self.std.is_some() {
            return; // std guard releases itself; waited guards already did
        }
        if let MutexFlavor::Model { id, .. } = &self.mx.inner {
            rt::mutex_release(*id);
        }
    }
}

// -------------------------------------------------------------- Condvar

enum CondvarFlavor {
    Std(std::sync::Condvar),
    Model { res: usize },
}

/// Dual-flavor condition variable (`wait`, `notify_one`, `notify_all`).
pub struct Condvar {
    flavor: CondvarFlavor,
}

impl Condvar {
    pub fn new() -> Condvar {
        if rt::active() {
            Condvar { flavor: CondvarFlavor::Model { res: rt::new_resource() } }
        } else {
            Condvar { flavor: CondvarFlavor::Std(std::sync::Condvar::new()) }
        }
    }

    pub fn wait<'a, T>(&self, mut guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
        match &self.flavor {
            CondvarFlavor::Std(cv) => {
                let mx = guard.mx;
                let std_guard =
                    guard.std.take().expect("std condvar paired with a model mutex");
                guard.released = true;
                drop(guard);
                match cv.wait(std_guard) {
                    Ok(g) => Ok(MutexGuard { mx, std: Some(g), released: false }),
                    Err(poison) => Err(PoisonError::new(MutexGuard {
                        mx,
                        std: Some(poison.into_inner()),
                        released: false,
                    })),
                }
            }
            CondvarFlavor::Model { res } => {
                let mx = guard.mx;
                let id = match &mx.inner {
                    MutexFlavor::Model { id, .. } => *id,
                    MutexFlavor::Std(_) => panic!("model condvar paired with a std mutex"),
                };
                // Atomic release-and-park: between mutex_release and
                // block_on_resource there is no yield point, so no
                // other thread can run and a notify cannot be lost.
                guard.released = true;
                drop(guard);
                rt::mutex_release(id);
                rt::block_on_resource(*res);
                mx.lock()
            }
        }
    }

    pub fn notify_one(&self) {
        match &self.flavor {
            CondvarFlavor::Std(cv) => cv.notify_one(),
            CondvarFlavor::Model { res } => {
                rt::yield_point();
                rt::wake_resource(*res);
            }
        }
    }

    pub fn notify_all(&self) {
        match &self.flavor {
            CondvarFlavor::Std(cv) => cv.notify_all(),
            CondvarFlavor::Model { res } => {
                rt::yield_point();
                rt::wake_resource(*res);
            }
        }
    }
}

impl Default for Condvar {
    fn default() -> Condvar {
        Condvar::new()
    }
}

impl fmt::Debug for Condvar {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Condvar").finish_non_exhaustive()
    }
}

// -------------------------------------------------------------- atomics

/// Dual-flavor atomics: one yield point precedes each operation on a
/// model thread, and every ordering is strengthened to `SeqCst`.
pub mod atomic {
    use super::rt;
    pub use std::sync::atomic::Ordering;

    macro_rules! model_int_atomic {
        ($name:ident, $prim:ty, $std:ty) => {
            #[derive(Debug, Default)]
            pub struct $name {
                inner: $std,
            }

            impl $name {
                pub const fn new(v: $prim) -> $name {
                    $name { inner: <$std>::new(v) }
                }

                fn order(&self, order: Ordering) -> Ordering {
                    if rt::active() {
                        rt::yield_point();
                        Ordering::SeqCst
                    } else {
                        order
                    }
                }

                pub fn load(&self, order: Ordering) -> $prim {
                    let o = self.order(order);
                    self.inner.load(o)
                }

                pub fn store(&self, v: $prim, order: Ordering) {
                    let o = self.order(order);
                    self.inner.store(v, o)
                }

                pub fn swap(&self, v: $prim, order: Ordering) -> $prim {
                    let o = self.order(order);
                    self.inner.swap(v, o)
                }

                pub fn fetch_add(&self, v: $prim, order: Ordering) -> $prim {
                    let o = self.order(order);
                    self.inner.fetch_add(v, o)
                }

                pub fn fetch_sub(&self, v: $prim, order: Ordering) -> $prim {
                    let o = self.order(order);
                    self.inner.fetch_sub(v, o)
                }
            }
        };
    }

    model_int_atomic!(AtomicU64, u64, std::sync::atomic::AtomicU64);
    model_int_atomic!(AtomicUsize, usize, std::sync::atomic::AtomicUsize);

    #[derive(Debug, Default)]
    pub struct AtomicBool {
        inner: std::sync::atomic::AtomicBool,
    }

    impl AtomicBool {
        pub const fn new(v: bool) -> AtomicBool {
            AtomicBool { inner: std::sync::atomic::AtomicBool::new(v) }
        }

        fn order(&self, order: Ordering) -> Ordering {
            if rt::active() {
                rt::yield_point();
                Ordering::SeqCst
            } else {
                order
            }
        }

        pub fn load(&self, order: Ordering) -> bool {
            let o = self.order(order);
            self.inner.load(o)
        }

        pub fn store(&self, v: bool, order: Ordering) {
            let o = self.order(order);
            self.inner.store(v, o)
        }

        pub fn swap(&self, v: bool, order: Ordering) -> bool {
            let o = self.order(order);
            self.inner.swap(v, o)
        }
    }
}

// ------------------------------------------------------------- channels

/// Dual-flavor mpsc with the subset of `std::sync::mpsc` the
/// coordinator uses (`channel`, `sync_channel`, `send`, `try_send`,
/// `recv`, `try_recv`, `recv_timeout`). Reuses `std`'s error types so
/// call sites match on the same variants in both flavors.
pub mod mpsc {
    use super::{host_lock, rt, Arc, Duration, StdMutex, VecDeque};
    use std::fmt;
    pub use std::sync::mpsc::{
        RecvError, RecvTimeoutError, SendError, TryRecvError, TrySendError,
    };

    struct ChanState<T> {
        q: VecDeque<T>,
        cap: Option<usize>,
        senders: usize,
        receiver_alive: bool,
    }

    /// Model-flavor channel core. Public only because the enum
    /// variants below name it; fields stay private.
    #[doc(hidden)]
    pub struct Chan<T> {
        res: usize,
        state: StdMutex<ChanState<T>>,
    }

    impl<T> Chan<T> {
        fn new(cap: Option<usize>) -> Arc<Chan<T>> {
            Arc::new(Chan {
                res: rt::new_resource(),
                state: StdMutex::new(ChanState {
                    q: VecDeque::new(),
                    cap,
                    senders: 1,
                    receiver_alive: true,
                }),
            })
        }

        fn send(&self, t: T) -> Result<(), SendError<T>> {
            let mut item = Some(t);
            loop {
                if rt::cancelled() {
                    return Err(SendError(item.take().expect("send item present")));
                }
                rt::yield_point();
                {
                    let mut st = host_lock(&self.state);
                    if !st.receiver_alive {
                        return Err(SendError(item.take().expect("send item present")));
                    }
                    let has_room = st.cap.map(|c| st.q.len() < c).unwrap_or(true);
                    if has_room {
                        st.q.push_back(item.take().expect("send item present"));
                        drop(st);
                        rt::wake_resource(self.res);
                        return Ok(());
                    }
                }
                rt::block_on_resource(self.res);
            }
        }

        fn try_send(&self, t: T) -> Result<(), TrySendError<T>> {
            rt::yield_point();
            let mut st = host_lock(&self.state);
            if !st.receiver_alive {
                return Err(TrySendError::Disconnected(t));
            }
            if let Some(cap) = st.cap {
                if st.q.len() >= cap {
                    return Err(TrySendError::Full(t));
                }
            }
            st.q.push_back(t);
            drop(st);
            rt::wake_resource(self.res);
            Ok(())
        }

        fn recv(&self) -> Result<T, RecvError> {
            loop {
                if rt::cancelled() {
                    return Err(RecvError);
                }
                rt::yield_point();
                {
                    let mut st = host_lock(&self.state);
                    if let Some(v) = st.q.pop_front() {
                        drop(st);
                        // Bounded senders may be parked waiting for room.
                        rt::wake_resource(self.res);
                        return Ok(v);
                    }
                    if st.senders == 0 {
                        return Err(RecvError);
                    }
                }
                rt::block_on_resource(self.res);
            }
        }

        fn try_recv(&self) -> Result<T, TryRecvError> {
            rt::yield_point();
            let mut st = host_lock(&self.state);
            match st.q.pop_front() {
                Some(v) => {
                    drop(st);
                    rt::wake_resource(self.res);
                    Ok(v)
                }
                None if st.senders == 0 => Err(TryRecvError::Disconnected),
                None => Err(TryRecvError::Empty),
            }
        }

        /// Model semantics: the checker has no clock, so an empty queue
        /// "times out" immediately — the timeout branch is just one
        /// more schedulable outcome of the event loop.
        fn recv_timeout(&self) -> Result<T, RecvTimeoutError> {
            match self.try_recv() {
                Ok(v) => Ok(v),
                Err(TryRecvError::Disconnected) => Err(RecvTimeoutError::Disconnected),
                Err(TryRecvError::Empty) => Err(RecvTimeoutError::Timeout),
            }
        }

        fn drop_sender(&self) {
            let mut st = host_lock(&self.state);
            st.senders -= 1;
            let disconnected = st.senders == 0;
            drop(st);
            if disconnected {
                rt::wake_resource(self.res);
            }
        }

        fn drop_receiver(&self) {
            let mut st = host_lock(&self.state);
            st.receiver_alive = false;
            drop(st);
            rt::wake_resource(self.res);
        }

        fn add_sender(&self) {
            host_lock(&self.state).senders += 1;
        }
    }

    pub enum Sender<T> {
        Std(std::sync::mpsc::Sender<T>),
        Model(Arc<Chan<T>>),
    }

    pub enum SyncSender<T> {
        Std(std::sync::mpsc::SyncSender<T>),
        Model(Arc<Chan<T>>),
    }

    pub enum Receiver<T> {
        Std(std::sync::mpsc::Receiver<T>),
        Model(Arc<Chan<T>>),
    }

    pub fn channel<T>() -> (Sender<T>, Receiver<T>) {
        if rt::active() {
            let ch = Chan::new(None);
            (Sender::Model(Arc::clone(&ch)), Receiver::Model(ch))
        } else {
            let (tx, rx) = std::sync::mpsc::channel();
            (Sender::Std(tx), Receiver::Std(rx))
        }
    }

    pub fn sync_channel<T>(bound: usize) -> (SyncSender<T>, Receiver<T>) {
        if rt::active() {
            let ch = Chan::new(Some(bound));
            (SyncSender::Model(Arc::clone(&ch)), Receiver::Model(ch))
        } else {
            let (tx, rx) = std::sync::mpsc::sync_channel(bound);
            (SyncSender::Std(tx), Receiver::Std(rx))
        }
    }

    impl<T> Sender<T> {
        pub fn send(&self, t: T) -> Result<(), SendError<T>> {
            match self {
                Sender::Std(tx) => tx.send(t),
                Sender::Model(ch) => ch.send(t),
            }
        }
    }

    impl<T> Clone for Sender<T> {
        fn clone(&self) -> Sender<T> {
            match self {
                Sender::Std(tx) => Sender::Std(tx.clone()),
                Sender::Model(ch) => {
                    ch.add_sender();
                    Sender::Model(Arc::clone(ch))
                }
            }
        }
    }

    impl<T> Drop for Sender<T> {
        fn drop(&mut self) {
            if let Sender::Model(ch) = self {
                ch.drop_sender();
            }
        }
    }

    impl<T> SyncSender<T> {
        pub fn send(&self, t: T) -> Result<(), SendError<T>> {
            match self {
                SyncSender::Std(tx) => tx.send(t),
                SyncSender::Model(ch) => ch.send(t),
            }
        }

        pub fn try_send(&self, t: T) -> Result<(), TrySendError<T>> {
            match self {
                SyncSender::Std(tx) => tx.try_send(t),
                SyncSender::Model(ch) => ch.try_send(t),
            }
        }
    }

    impl<T> Clone for SyncSender<T> {
        fn clone(&self) -> SyncSender<T> {
            match self {
                SyncSender::Std(tx) => SyncSender::Std(tx.clone()),
                SyncSender::Model(ch) => {
                    ch.add_sender();
                    SyncSender::Model(Arc::clone(ch))
                }
            }
        }
    }

    impl<T> Drop for SyncSender<T> {
        fn drop(&mut self) {
            if let SyncSender::Model(ch) = self {
                ch.drop_sender();
            }
        }
    }

    impl<T> Receiver<T> {
        pub fn recv(&self) -> Result<T, RecvError> {
            match self {
                Receiver::Std(rx) => rx.recv(),
                Receiver::Model(ch) => ch.recv(),
            }
        }

        pub fn try_recv(&self) -> Result<T, TryRecvError> {
            match self {
                Receiver::Std(rx) => rx.try_recv(),
                Receiver::Model(ch) => ch.try_recv(),
            }
        }

        pub fn recv_timeout(&self, timeout: Duration) -> Result<T, RecvTimeoutError> {
            match self {
                Receiver::Std(rx) => rx.recv_timeout(timeout),
                Receiver::Model(ch) => ch.recv_timeout(),
            }
        }
    }

    impl<T> Drop for Receiver<T> {
        fn drop(&mut self) {
            if let Receiver::Model(ch) = self {
                ch.drop_receiver();
            }
        }
    }

    impl<T> fmt::Debug for Sender<T> {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            f.write_str("Sender { .. }")
        }
    }
    impl<T> fmt::Debug for SyncSender<T> {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            f.write_str("SyncSender { .. }")
        }
    }
    impl<T> fmt::Debug for Receiver<T> {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            f.write_str("Receiver { .. }")
        }
    }
}

// -------------------------------------------------------------- threads

/// Dual-flavor thread spawn/join/sleep/yield. Model threads are
/// checker-scheduled; the builder name is dropped in that flavor (the
/// checker names threads by tid).
pub mod thread {
    use super::{host_lock, rt, Arc, Duration, StdMutex};

    pub struct Builder {
        name: Option<String>,
    }

    impl Builder {
        pub fn new() -> Builder {
            Builder { name: None }
        }

        pub fn name(mut self, name: String) -> Builder {
            self.name = Some(name);
            self
        }

        pub fn spawn<F, T>(self, f: F) -> std::io::Result<JoinHandle<T>>
        where
            F: FnOnce() -> T + Send + 'static,
            T: Send + 'static,
        {
            if rt::active() {
                let slot: Arc<StdMutex<Option<T>>> = Arc::new(StdMutex::new(None));
                let out = Arc::clone(&slot);
                let tid = rt::spawn(move || {
                    let v = f();
                    *host_lock(&out) = Some(v);
                });
                Ok(JoinHandle::Model { tid, slot })
            } else {
                let mut b = std::thread::Builder::new();
                if let Some(n) = self.name {
                    b = b.name(n);
                }
                b.spawn(f).map(JoinHandle::Std)
            }
        }
    }

    impl Default for Builder {
        fn default() -> Builder {
            Builder::new()
        }
    }

    pub enum JoinHandle<T> {
        Std(std::thread::JoinHandle<T>),
        Model { tid: usize, slot: Arc<StdMutex<Option<T>>> },
    }

    impl<T> JoinHandle<T> {
        pub fn join(self) -> std::thread::Result<T> {
            match self {
                JoinHandle::Std(h) => h.join(),
                JoinHandle::Model { tid, slot } => {
                    rt::join(tid);
                    match host_lock(&slot).take() {
                        Some(v) => Ok(v),
                        None => Err(Box::new(
                            "model thread ended without a value (panicked or cancelled)"
                                .to_string(),
                        )),
                    }
                }
            }
        }
    }

    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        Builder::new().spawn(f).expect("facade thread spawn")
    }

    /// Model flavor: the checker has no clock — sleeping is just a
    /// scheduling opportunity.
    pub fn sleep(dur: Duration) {
        if rt::active() {
            rt::yield_point();
        } else {
            std::thread::sleep(dur);
        }
    }

    pub fn yield_now() {
        if rt::active() {
            rt::yield_point();
        } else {
            std::thread::yield_now();
        }
    }
}
