//! Provenance-preserving `Send` pointer wrappers for the shard
//! scheduler's lease discipline.
//!
//! The scheduler hands its workers raw pointers to shard state and
//! batch slices that are guaranteed disjoint and outlive the job (the
//! *lease*: inject → execute → finish brackets every access). Before
//! this module the pointers were laundered through `usize` casts to
//! make them `Send`, which destroys provenance under strict-provenance
//! analysis (and Miri). These newtypes keep the pointer a pointer —
//! same `Send` effect, no integer round-trip — and are the only place
//! the `lint` binary's ptr-cast rule whitelists.
//!
//! Safety protocol shared by all three types:
//!
//! * `new` captures the pointer (and length) from a live reference, so
//!   the wrapper starts with valid provenance for the whole referent.
//! * The creator must guarantee the referent outlives every dereference
//!   and that no aliasing access happens concurrently — in the
//!   scheduler this is the phase lease: the submitting thread blocks in
//!   `finish()` until every injected chunk has executed before touching
//!   the data again.
//! * The unsafe `as_*` methods re-materialise the reference with a
//!   caller-chosen lifetime; the caller asserts the lease is still
//!   open.

use std::marker::PhantomData;

/// A `Send`able raw `*mut T` with provenance intact. One exclusive
/// referent — the scheduler sends exactly one per shard per chunk that
/// mutates it.
#[derive(Debug)]
pub struct SendPtr<T> {
    ptr: *mut T,
    _marker: PhantomData<*mut T>,
}

// SAFETY: SendPtr is a capability to access one `T` exclusively under
// the creator's lease discipline (no concurrent aliasing access for
// the wrapper's lifetime). Moving that capability to another thread is
// sound exactly when moving a `&mut T` would be, hence `T: Send`.
unsafe impl<T: Send> Send for SendPtr<T> {}

impl<T> SendPtr<T> {
    /// Wrap an exclusive reference. (Callers pass `&mut T`; the
    /// coercion to `*mut T` happens at the call site.)
    pub fn new(ptr: *mut T) -> SendPtr<T> {
        SendPtr { ptr, _marker: PhantomData }
    }

    /// Re-materialise the exclusive reference.
    ///
    /// # Safety
    /// The referent must still be alive and the lease still open: no
    /// other reference (shared or exclusive) to the referent may be
    /// used for the duration of `'a`.
    pub unsafe fn deref_mut<'a>(self) -> &'a mut T {
        // SAFETY: caller upholds liveness + exclusivity per the module
        // protocol; the pointer carries provenance from `new`'s source
        // reference.
        unsafe { &mut *self.ptr }
    }

    /// Re-materialise a *shared* reference. Several copies of the same
    /// `SendPtr` may hold shared references concurrently (the scheduler
    /// hands multiple gather chunks read access to one shard).
    ///
    /// # Safety
    /// The referent must still be alive for `'a` and no exclusive
    /// access to it (through this wrapper or otherwise) may be used
    /// during `'a`.
    pub unsafe fn deref_ref<'a>(self) -> &'a T {
        // SAFETY: caller upholds liveness + no-writer per the module
        // protocol; the pointer carries provenance from `new`'s source
        // reference, and shared aliasing among readers is sound.
        unsafe { &*self.ptr }
    }
}

impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> SendPtr<T> {
        SendPtr { ptr: self.ptr, _marker: PhantomData }
    }
}
impl<T> Copy for SendPtr<T> {}

/// A `Send`able shared slice (`&[T]` flattened to pointer + len).
#[derive(Debug)]
pub struct SendSlice<T> {
    ptr: *const T,
    len: usize,
    _marker: PhantomData<*const T>,
}

// SAFETY: a SendSlice is a read-only capability over `[T]`; sharing it
// across threads is sound when `&[T]` would be, hence `T: Sync`.
unsafe impl<T: Sync> Send for SendSlice<T> {}

impl<T> SendSlice<T> {
    pub fn new(slice: &[T]) -> SendSlice<T> {
        SendSlice { ptr: slice.as_ptr(), len: slice.len(), _marker: PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Re-materialise the shared slice.
    ///
    /// # Safety
    /// The slice data must still be alive for `'a`, with no exclusive
    /// access to it used during `'a`.
    pub unsafe fn as_slice<'a>(self) -> &'a [T] {
        // SAFETY: caller upholds liveness + no-writer per the module
        // protocol; ptr/len came from a real slice in `new`.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }
}

impl<T> Clone for SendSlice<T> {
    fn clone(&self) -> SendSlice<T> {
        SendSlice { ptr: self.ptr, len: self.len, _marker: PhantomData }
    }
}
impl<T> Copy for SendSlice<T> {}

/// A `Send`able exclusive slice (`&mut [T]` flattened to pointer +
/// len). The scheduler carves gather destinations into disjoint
/// wrappers with `split_at_mut` *before* wrapping, so two wrappers
/// never alias.
#[derive(Debug)]
pub struct SendSliceMut<T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<*mut T>,
}

// SAFETY: exclusive capability over `[T]` under the lease discipline;
// sound to move across threads when `&mut [T]` would be (`T: Send`).
unsafe impl<T: Send> Send for SendSliceMut<T> {}

impl<T> SendSliceMut<T> {
    pub fn new(slice: &mut [T]) -> SendSliceMut<T> {
        SendSliceMut { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: PhantomData }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Re-materialise the exclusive slice.
    ///
    /// # Safety
    /// The slice data must still be alive for `'a` and this wrapper
    /// must be the only access path used during `'a` (the wrappers are
    /// carved disjoint at creation; the lease keeps the parent slice
    /// untouched until join).
    pub unsafe fn as_mut_slice<'a>(self) -> &'a mut [T] {
        // SAFETY: caller upholds liveness + exclusivity per the module
        // protocol; ptr/len came from a real exclusive slice in `new`.
        unsafe { std::slice::from_raw_parts_mut(self.ptr, self.len) }
    }
}

impl<T> Clone for SendSliceMut<T> {
    fn clone(&self) -> SendSliceMut<T> {
        SendSliceMut { ptr: self.ptr, len: self.len, _marker: PhantomData }
    }
}
impl<T> Copy for SendSliceMut<T> {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sendptr_round_trips_exclusive_access() {
        let mut x = 41u32;
        let p = SendPtr::new(&mut x);
        // SAFETY: `x` is alive and no other reference is used while
        // the re-materialised one exists.
        let r = unsafe { p.deref_mut() };
        *r += 1;
        assert_eq!(x, 42);
    }

    #[test]
    fn sendptr_shared_reads_may_alias() {
        let mut x = 7u32;
        let p = SendPtr::new(&mut x);
        // SAFETY: `x` is alive and nobody writes it while the two
        // shared re-materialisations exist.
        let (a, b) = unsafe { (p.deref_ref(), p.deref_ref()) };
        assert_eq!(*a + *b, 14);
    }

    #[test]
    fn send_slices_round_trip_and_report_len() {
        let data = [1.0f32, 2.0, 3.0];
        let s = SendSlice::new(&data);
        assert_eq!(s.len(), 3);
        assert!(!s.is_empty());
        // SAFETY: `data` is alive, nobody writes it.
        assert_eq!(unsafe { s.as_slice() }, &[1.0, 2.0, 3.0]);

        let mut buf = [0.0f32; 4];
        let (head, tail) = buf.split_at_mut(2);
        let a = SendSliceMut::new(head);
        let b = SendSliceMut::new(tail);
        assert_eq!(a.len(), 2);
        // SAFETY: a and b were carved disjoint; buf is alive.
        unsafe { a.as_mut_slice() }.fill(1.5);
        // SAFETY: as above.
        unsafe { b.as_mut_slice() }.fill(2.5);
        assert_eq!(buf, [1.5, 1.5, 2.5, 2.5]);
    }

    #[test]
    fn wrappers_are_send() {
        fn assert_send<T: Send>() {}
        assert_send::<SendPtr<u32>>();
        assert_send::<SendSlice<f32>>();
        assert_send::<SendSliceMut<f32>>();
    }

    #[test]
    fn empty_slices_are_fine() {
        let empty: [f32; 0] = [];
        let s = SendSlice::new(&empty);
        assert!(s.is_empty());
        // SAFETY: zero-length slices are always valid to form.
        assert_eq!(unsafe { s.as_slice() }.len(), 0);
    }
}
