//! Property-based testing harness (offline `proptest` replacement).
//!
//! Provides seeded generators and a `check` runner with automatic input
//! shrinking: on failure it greedily tries smaller variants of the failing
//! case (halving sizes / values, dropping elements) until no smaller
//! counterexample reproduces, then panics with the minimal case and the
//! seed needed to replay it.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::util::rng::Rng;

// ---------------- allocation counting ----------------

static HEAP_ACQUISITIONS: AtomicU64 = AtomicU64::new(0);

/// A counting wrapper around the system allocator for allocation-
/// regression tests: install it as the `#[global_allocator]` of a
/// *dedicated* integration-test binary (one test per binary, so no
/// concurrent test thread muddies the counter) and diff
/// [`CountingAlloc::allocations`] around the code under test.
///
/// Counts heap *acquisitions* — `alloc`, `alloc_zeroed` and `realloc`
/// (a grow is a new acquisition even when it happens to extend in
/// place); `dealloc` is free. A steady-state loop that reports a zero
/// delta therefore provably never touched the allocator.
pub struct CountingAlloc;

impl CountingAlloc {
    pub const fn new() -> CountingAlloc {
        CountingAlloc
    }

    /// Heap acquisitions since process start.
    pub fn allocations() -> u64 {
        HEAP_ACQUISITIONS.load(Ordering::Relaxed)
    }
}

// SAFETY: a pure pass-through to `System` plus a relaxed counter bump —
// every GlobalAlloc contract obligation (layout validity, pointer
// ownership, no unwinding) is discharged by delegating to `System`
// unchanged, and the counter has no effect on allocation behaviour.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        HEAP_ACQUISITIONS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: caller upholds GlobalAlloc's contract for `layout`;
        // we forward it to the system allocator unchanged.
        unsafe { System.alloc(layout) }
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        HEAP_ACQUISITIONS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: caller upholds GlobalAlloc's contract for `layout`;
        // we forward it to the system allocator unchanged.
        unsafe { System.alloc_zeroed(layout) }
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        HEAP_ACQUISITIONS.fetch_add(1, Ordering::Relaxed);
        // SAFETY: caller guarantees `ptr` was allocated here with
        // `layout` and that `new_size` is valid; forwarded unchanged.
        unsafe { System.realloc(ptr, layout, new_size) }
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        // SAFETY: caller guarantees `ptr`/`layout` match the original
        // allocation; forwarded unchanged.
        unsafe { System.dealloc(ptr, layout) }
    }
}

// ---------------- property harness ----------------

/// A generator of random values of `T` with a shrink strategy.
pub trait Gen {
    type Value: std::fmt::Debug + Clone;

    /// Sample a value.
    fn sample(&self, rng: &mut Rng) -> Self::Value;

    /// Candidate shrinks of `v`, in decreasing aggressiveness.
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let _ = v;
        Vec::new()
    }
}

/// Number of cases per property (keep CI fast but meaningful).
pub const DEFAULT_CASES: u32 = 128;

/// Run a property over `cases` random inputs; panics with the minimal
/// failing input if the property returns `Err`.
pub fn check<G: Gen>(name: &str, seed: u64, cases: u32, gen: &G, prop: impl Fn(&G::Value) -> Result<(), String>) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen.sample(&mut rng);
        if let Err(msg) = prop(&input) {
            // Shrink greedily.
            let mut best = input.clone();
            let mut best_msg = msg;
            let mut improved = true;
            let mut rounds = 0;
            while improved && rounds < 1000 {
                improved = false;
                rounds += 1;
                for cand in gen.shrink(&best) {
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        improved = true;
                        break;
                    }
                }
            }
            panic!(
                "property '{name}' failed (seed {seed}, case {case}):\n  minimal input: {best:?}\n  error: {best_msg}"
            );
        }
    }
}

// ---------------- generators ----------------

/// u64 in [lo, hi].
pub struct U64Range {
    pub lo: u64,
    pub hi: u64,
}

impl Gen for U64Range {
    type Value = u64;

    fn sample(&self, rng: &mut Rng) -> u64 {
        rng.range(self.lo, self.hi + 1)
    }

    fn shrink(&self, v: &u64) -> Vec<u64> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*v - self.lo) / 2);
            out.push(*v - 1);
        }
        out.dedup();
        out
    }
}

/// Vec of u32 counts with bounded length and value (insertion count
/// vectors).
pub struct CountsVec {
    pub max_len: usize,
    pub max_val: u32,
}

impl Gen for CountsVec {
    type Value = Vec<u32>;

    fn sample(&self, rng: &mut Rng) -> Vec<u32> {
        let len = rng.range(0, self.max_len as u64 + 1) as usize;
        (0..len).map(|_| rng.range(0, self.max_val as u64 + 1) as u32).collect()
    }

    fn shrink(&self, v: &Vec<u32>) -> Vec<Vec<u32>> {
        let mut out = Vec::new();
        if !v.is_empty() {
            out.push(v[..v.len() / 2].to_vec()); // drop back half
            out.push(v[v.len() / 2..].to_vec()); // drop front half
            let mut halved = v.clone();
            for x in &mut halved {
                *x /= 2;
            }
            if &halved != v {
                out.push(halved);
            }
            let mut minus_one = v.clone();
            minus_one.pop();
            out.push(minus_one);
        }
        out
    }
}

/// Pairs of independent generators.
pub struct PairGen<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for PairGen<A, B> {
    type Value = (A::Value, B::Value);

    fn sample(&self, rng: &mut Rng) -> Self::Value {
        (self.0.sample(rng), self.1.sample(rng))
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        for a in self.0.shrink(&v.0) {
            out.push((a, v.1.clone()));
        }
        for b in self.1.shrink(&v.1) {
            out.push((v.0.clone(), b));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let ran = std::cell::Cell::new(0u32);
        let gen = U64Range { lo: 0, hi: 100 };
        check("tautology", 1, 50, &gen, |_| {
            ran.set(ran.get() + 1);
            Ok(())
        });
        assert_eq!(ran.get(), 50);
    }

    #[test]
    #[should_panic(expected = "minimal input: 50")]
    fn shrinks_to_boundary() {
        // Property "v < 50" fails first at some v ≥ 50; shrinking must
        // land exactly on 50.
        let gen = U64Range { lo: 0, hi: 1000 };
        check("v<50", 7, 200, &gen, |&v| if v < 50 { Ok(()) } else { Err(format!("{v} !< 50")) });
    }

    #[test]
    #[should_panic]
    fn counts_vec_shrinks_length() {
        let gen = CountsVec { max_len: 64, max_val: 10 };
        check("len<5", 3, 100, &gen, |v| {
            if v.len() < 5 {
                Ok(())
            } else {
                Err("too long".into())
            }
        });
    }

    #[test]
    fn counts_vec_samples_in_bounds() {
        let gen = CountsVec { max_len: 16, max_val: 9 };
        let mut rng = Rng::new(11);
        for _ in 0..100 {
            let v = gen.sample(&mut rng);
            assert!(v.len() <= 16);
            assert!(v.iter().all(|&x| x <= 9));
        }
    }

    #[test]
    fn pair_gen_shrinks_componentwise() {
        let gen = PairGen(U64Range { lo: 0, hi: 10 }, U64Range { lo: 0, hi: 10 });
        let shrinks = gen.shrink(&(5, 7));
        assert!(shrinks.iter().any(|&(a, b)| a < 5 && b == 7));
        assert!(shrinks.iter().any(|&(a, b)| a == 5 && b < 7));
    }
}
