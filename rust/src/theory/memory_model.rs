//! Monte-Carlo + closed-form memory model behind Fig 3.

use crate::util::math::{lognormal_mean, lognormal_quantile, next_pow2};
use crate::util::rng::Rng;

/// Expected memory (relative to `s`, the base size) of every structure at
/// one σ.
#[derive(Debug, Clone, Copy)]
pub struct UsagePoint {
    pub sigma: f64,
    /// E\[n\]/s — the oracle provision.
    pub optimal: f64,
    /// q99 static provision (1% failure budget).
    pub static_p99: f64,
    /// E\[peak\] of the copy-doubling array (transient 3×).
    pub semistatic: f64,
    /// E\[peak\] of the memMap doubling array (2× policy, no copy).
    pub memmap: f64,
    /// E\[GGArray capacity\] — doubling buckets per LFVector.
    pub ggarray: f64,
    /// Worst-case GGArray capacity/size ratio observed among draws in the
    /// asymptotic regime (n ≥ 4·B·fbs). §V's "not greater than 2×" is an
    /// asymptotic statement: right after a bucket boundary the ratio is
    /// (2^k−1)/(2^{k−1}−1) = 3, 2.33, 2.14 … → 2, and below the
    /// first-bucket floor (n < B·fbs) the ratio is dominated by the fixed
    /// B·fbs minimum rather than the doubling policy — those draws are
    /// excluded here and visible in `ggarray` (the expectation) instead.
    pub ggarray_worst_ratio: f64,
}

/// The full Fig 3 curve.
#[derive(Debug, Clone)]
pub struct MemoryCurve {
    pub points: Vec<UsagePoint>,
}

/// GGArray capacity for `n` live elements spread over `blocks` LFVectors
/// with first-bucket size `fbs` (each LFVector holds ≈ n/B and rounds up
/// to its bucket envelope `fbs·(2^k − 1)`).
pub fn ggarray_capacity(n: u64, blocks: u64, fbs: u64) -> u64 {
    if n == 0 {
        return 0;
    }
    let per = crate::util::math::ceil_div(n, blocks);
    // smallest k with fbs(2^k − 1) ≥ per ⇒ envelope capacity
    let k = {
        let blocks_needed = crate::util::math::ceil_div(per + fbs, fbs);
        64 - (blocks_needed - 1).leading_zeros() as u64
    };
    let cap_per = fbs * ((1u64 << k) - 1);
    cap_per * blocks
}

/// Doubling-array capacity (next power of two ≥ n).
pub fn doubling_capacity(n: u64) -> u64 {
    next_pow2(n.max(1))
}

/// Compute one σ point by Monte-Carlo over `draws` workloads of base size
/// `s` elements (unit element size — everything is reported relative to
/// `s`).
pub fn expected_usage(sigma: f64, s: u64, blocks: u64, fbs: u64, draws: u32, rng: &mut Rng) -> UsagePoint {
    let mut sum_n = 0.0;
    let mut sum_semi = 0.0;
    let mut sum_mm = 0.0;
    let mut sum_gg = 0.0;
    let mut worst_gg = 0.0f64;
    for _ in 0..draws {
        let x = if sigma == 0.0 { 1.0 } else { rng.lognormal(0.0, sigma) };
        let n = ((s as f64) * x).max(1.0) as u64;
        sum_n += n as f64;
        // Copy-doubling: capacity 2^k ≥ n, transient peak = cap/2 + cap
        // (old + new live simultaneously during the final resize).
        let cap = doubling_capacity(n) as f64;
        sum_semi += cap + cap / 2.0;
        // memMap: same doubling capacity policy, but no copy ⇒ peak = cap.
        sum_mm += cap;
        let gg = ggarray_capacity(n, blocks, fbs) as f64;
        sum_gg += gg;
        if n >= 4 * blocks * fbs {
            worst_gg = worst_gg.max(gg / n as f64);
        }
    }
    let d = draws as f64;
    let sf = s as f64;
    UsagePoint {
        sigma,
        optimal: sum_n / d / sf,
        static_p99: lognormal_quantile(0.99, 0.0, sigma),
        semistatic: sum_semi / d / sf,
        memmap: sum_mm / d / sf,
        ggarray: sum_gg / d / sf,
        ggarray_worst_ratio: worst_gg,
    }
}

/// Sweep σ ∈ [0, max_sigma] with `steps` points (Fig 3's x-axis).
pub fn sweep(max_sigma: f64, steps: u32, s: u64, blocks: u64, fbs: u64, draws: u32, seed: u64) -> MemoryCurve {
    let mut rng = Rng::new(seed);
    let points = (0..=steps)
        .map(|i| {
            let sigma = max_sigma * i as f64 / steps as f64;
            expected_usage(sigma, s, blocks, fbs, draws, &mut rng)
        })
        .collect();
    MemoryCurve { points }
}

/// Closed-form E[X] for reference: `exp(σ²/2)`.
pub fn optimal_closed_form(sigma: f64) -> f64 {
    lognormal_mean(0.0, sigma)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ggarray_capacity_bounds() {
        // capacity ∈ [n, 2n + B·fbs) for all n.
        for &n in &[1u64, 100, 1023, 1024, 1025, 1_000_000, 123_456_789] {
            for &b in &[1u64, 32, 512] {
                let cap = ggarray_capacity(n, b, 1024);
                assert!(cap >= n, "cap {cap} < n {n} (B={b})");
                assert!(
                    cap as f64 <= 2.0 * n as f64 + (2.0 * b as f64 * 1024.0),
                    "cap {cap} vs n {n} B={b}"
                );
            }
        }
        assert_eq!(ggarray_capacity(0, 32, 1024), 0);
    }

    #[test]
    fn doubling_capacity_values() {
        assert_eq!(doubling_capacity(1), 1);
        assert_eq!(doubling_capacity(1000), 1024);
        assert_eq!(doubling_capacity(1024), 1024);
        assert_eq!(doubling_capacity(1025), 2048);
    }

    #[test]
    fn sigma_zero_degenerates() {
        let mut rng = Rng::new(1);
        let p = expected_usage(0.0, 1_000_000, 512, 1024, 100, &mut rng);
        assert!((p.optimal - 1.0).abs() < 1e-9);
        assert!((p.static_p99 - 1.0).abs() < 1e-9);
        // GGArray overhead at exactly n=s: bounded by 2.
        assert!(p.ggarray >= 1.0 && p.ggarray < 2.1, "{}", p.ggarray);
    }

    #[test]
    fn fig3_shape_static_explodes_ggarray_stays_2x() {
        let mut rng = Rng::new(42);
        let lo = expected_usage(0.5, 1_000_000, 512, 64, 2000, &mut rng);
        let hi = expected_usage(2.0, 1_000_000, 512, 64, 2000, &mut rng);
        // Static provision grows explosively with σ.
        assert!(lo.static_p99 > 3.0 && lo.static_p99 < 3.5); // e^{2.326·0.5}≈3.2
        assert!(hi.static_p99 > 100.0); // e^{4.65}≈105
        // GGArray stays within 2× of optimal *in expectation* at every σ;
        // individual draws can reach ~3× near small bucket boundaries
        // (first-bucket floor — see `ggarray_worst_ratio` docs).
        assert!(lo.ggarray / lo.optimal < 2.05, "{}", lo.ggarray / lo.optimal);
        assert!(hi.ggarray / hi.optimal < 2.05, "{}", hi.ggarray / hi.optimal);
        assert!(lo.ggarray_worst_ratio < 2.2, "{}", lo.ggarray_worst_ratio);
        assert!(hi.ggarray_worst_ratio < 2.2, "{}", hi.ggarray_worst_ratio);
        // And beats the static provision decisively at high σ (~9.5×
        // less memory in expectation at σ=2).
        assert!(hi.ggarray < hi.static_p99 / 8.0);
    }

    #[test]
    fn semistatic_peak_above_memmap() {
        let mut rng = Rng::new(7);
        let p = expected_usage(1.0, 1_000_000, 512, 1024, 2000, &mut rng);
        assert!(p.semistatic > p.memmap, "{} !> {}", p.semistatic, p.memmap);
        assert!((p.semistatic / p.memmap - 1.5).abs() < 1e-9);
        // memMap (pow2 doubling) averages ~1.5× optimal, worst 2×.
        let ratio = p.memmap / p.optimal;
        assert!(ratio > 1.2 && ratio < 2.0, "{ratio}");
    }

    #[test]
    fn monte_carlo_matches_closed_form_mean() {
        let mut rng = Rng::new(123);
        let p = expected_usage(1.0, 1_000_000, 512, 1024, 20_000, &mut rng);
        let want = optimal_closed_form(1.0);
        assert!((p.optimal - want).abs() / want < 0.05, "mc {} cf {want}", p.optimal);
    }

    #[test]
    fn sweep_has_monotone_static_curve() {
        let curve = sweep(2.0, 10, 100_000, 512, 1024, 500, 9);
        assert_eq!(curve.points.len(), 11);
        for w in curve.points.windows(2) {
            assert!(w[1].static_p99 >= w[0].static_p99);
        }
    }
}
