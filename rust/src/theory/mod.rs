//! Theoretical memory-usage model (paper §V / Fig 3).
//!
//! Workload: an application whose final element count is `n = s·X` with
//! `X ~ LogNormal(0, σ)` — the amount of insertions is uncertain. How
//! much VRAM must each structure provision?
//!
//! * **optimal** — exactly `n` (oracle knowledge);
//! * **static** — must pre-allocate a high quantile of the distribution so
//!   the run fails at most 1% of the time: `s·q_{0.99}(X)`;
//! * **semi-static (doubling)** — holds `next_pow2` of the live size, and
//!   transiently `3×` during a copy-resize;
//! * **memMap** — doubling capacity in pages, no copy ⇒ peak `≈ 2n`;
//! * **GGArray** — per-LFVector doubling buckets: capacity < `2n + B·fbs`,
//!   i.e. asymptotically below `2×` optimal (§V: "not greater than 2×").

pub mod memory_model;

pub use memory_model::{expected_usage, MemoryCurve, UsagePoint};
