//! Minimal subcommand + flag argument parser (offline `clap` replacement)
//! for the `repro` CLI and the bench binaries.
//!
//! Supported syntax: `prog <subcommand> [--flag] [--key value] [--key=value]
//! [positional...]`. Unknown flags are errors; `--help` renders usage from
//! the declared specs.

use std::collections::BTreeMap;

/// Declared flag/option spec (for help rendering and validation).
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
    pub help: &'static str,
}

/// A declared subcommand.
#[derive(Debug, Clone)]
pub struct CmdSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub opts: Vec<OptSpec>,
}

/// Parse result: chosen subcommand, options, and positionals.
#[derive(Debug, Clone)]
pub struct Parsed {
    pub command: String,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Parsed {
    /// Value of `--name` (after defaults applied).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// Required option parse with error context.
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> anyhow::Result<T>
    where
        T::Err: std::fmt::Display,
    {
        let raw = self
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("missing required option --{name}"))?;
        raw.parse::<T>()
            .map_err(|e| anyhow::anyhow!("invalid value for --{name} ({raw}): {e}"))
    }

    /// Optional option with parsing.
    pub fn get_opt_parse<T: std::str::FromStr>(&self, name: &str) -> anyhow::Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(raw) => raw
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("invalid value for --{name} ({raw}): {e}")),
        }
    }

    /// Was boolean `--name` present?
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

/// The CLI definition.
#[derive(Debug, Clone)]
pub struct Cli {
    pub prog: &'static str,
    pub about: &'static str,
    pub commands: Vec<CmdSpec>,
    /// Options accepted by every subcommand.
    pub global_opts: Vec<OptSpec>,
}

impl Cli {
    /// Render `--help` text.
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} <command> [options]\n\nCOMMANDS:\n", self.prog, self.about, self.prog);
        for c in &self.commands {
            s.push_str(&format!("  {:<14} {}\n", c.name, c.help));
        }
        s.push_str("\nGLOBAL OPTIONS:\n");
        for o in &self.global_opts {
            s.push_str(&render_opt(o));
        }
        s.push_str("\nPer-command options are shown with `<command> --help`.\n");
        s
    }

    fn command_usage(&self, cmd: &CmdSpec) -> String {
        let mut s = format!("{} {} — {}\n\nOPTIONS:\n", self.prog, cmd.name, cmd.help);
        for o in cmd.opts.iter().chain(self.global_opts.iter()) {
            s.push_str(&render_opt(o));
        }
        s
    }

    /// Parse argv (not including argv[0]). Returns Err(help-text) for
    /// `--help` / no args so the caller can print and exit 0.
    pub fn parse(&self, args: &[String]) -> Result<Parsed, String> {
        if args.is_empty() || args[0] == "--help" || args[0] == "-h" || args[0] == "help" {
            return Err(self.usage());
        }
        let cmd_name = &args[0];
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| format!("unknown command '{cmd_name}'\n\n{}", self.usage()))?;

        let known = |name: &str| -> Option<&OptSpec> {
            cmd.opts
                .iter()
                .chain(self.global_opts.iter())
                .find(|o| o.name == name)
        };

        let mut parsed = Parsed {
            command: cmd.name.to_string(),
            opts: BTreeMap::new(),
            flags: Vec::new(),
            positional: Vec::new(),
        };
        // Apply defaults first.
        for o in cmd.opts.iter().chain(self.global_opts.iter()) {
            if let Some(d) = o.default {
                parsed.opts.insert(o.name.to_string(), d.to_string());
            }
        }

        let mut i = 1;
        while i < args.len() {
            let a = &args[i];
            if a == "--help" || a == "-h" {
                return Err(self.command_usage(cmd));
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline_val) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = known(&name).ok_or_else(|| {
                    format!("unknown option --{name} for '{}'\n\n{}", cmd.name, self.command_usage(cmd))
                })?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| format!("option --{name} requires a value"))?
                        }
                    };
                    parsed.opts.insert(name, val);
                } else {
                    if inline_val.is_some() {
                        return Err(format!("flag --{name} does not take a value"));
                    }
                    parsed.flags.push(name);
                }
            } else {
                parsed.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(parsed)
    }
}

fn render_opt(o: &OptSpec) -> String {
    let mut left = format!("--{}", o.name);
    if o.takes_value {
        left.push_str(" <v>");
    }
    let mut line = format!("  {left:<22} {}", o.help);
    if let Some(d) = o.default {
        line.push_str(&format!(" [default: {d}]"));
    }
    line.push('\n');
    line
}

/// Shorthand constructors.
pub fn opt(name: &'static str, default: Option<&'static str>, help: &'static str) -> OptSpec {
    OptSpec { name, takes_value: true, default, help }
}

pub fn flag(name: &'static str, help: &'static str) -> OptSpec {
    OptSpec { name, takes_value: false, default: None, help }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli {
            prog: "repro",
            about: "test",
            commands: vec![
                CmdSpec {
                    name: "fig5",
                    help: "run fig5",
                    opts: vec![
                        opt("blocks", Some("512"), "number of LFVectors"),
                        opt("gpu", Some("a100"), "device model"),
                        flag("verbose", "chatty"),
                    ],
                },
                CmdSpec { name: "all", help: "run everything", opts: vec![] },
            ],
            global_opts: vec![opt("seed", Some("42"), "rng seed"), opt("out", None, "output dir")],
        }
    }

    fn args(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_defaults_and_overrides() {
        let p = cli().parse(&args(&["fig5", "--blocks", "32", "--verbose"])).unwrap();
        assert_eq!(p.command, "fig5");
        assert_eq!(p.get("blocks"), Some("32"));
        assert_eq!(p.get("gpu"), Some("a100")); // default
        assert_eq!(p.get("seed"), Some("42")); // global default
        assert!(p.flag("verbose"));
        assert!(!p.flag("quiet"));
    }

    #[test]
    fn equals_syntax() {
        let p = cli().parse(&args(&["fig5", "--blocks=64", "--seed=7"])).unwrap();
        assert_eq!(p.get_parse::<u32>("blocks").unwrap(), 64);
        assert_eq!(p.get_parse::<u64>("seed").unwrap(), 7);
    }

    #[test]
    fn unknown_command_and_option() {
        assert!(cli().parse(&args(&["nope"])).is_err());
        assert!(cli().parse(&args(&["fig5", "--bogus", "1"])).is_err());
        // 'blocks' belongs to fig5, not 'all'
        assert!(cli().parse(&args(&["all", "--blocks", "1"])).is_err());
    }

    #[test]
    fn help_requested() {
        let err = cli().parse(&args(&[])).unwrap_err();
        assert!(err.contains("USAGE"));
        let err = cli().parse(&args(&["fig5", "--help"])).unwrap_err();
        assert!(err.contains("number of LFVectors"));
    }

    #[test]
    fn missing_value_is_error() {
        assert!(cli().parse(&args(&["fig5", "--blocks"])).is_err());
    }

    #[test]
    fn positionals_collected() {
        let p = cli().parse(&args(&["fig5", "pos1", "--blocks", "8", "pos2"])).unwrap();
        assert_eq!(p.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn get_parse_errors_are_descriptive() {
        let p = cli().parse(&args(&["fig5", "--blocks", "NaNs"])).unwrap();
        let e = p.get_parse::<u32>("blocks").unwrap_err().to_string();
        assert!(e.contains("--blocks"), "{e}");
    }
}
