//! Wall-clock benchmark harness (offline `criterion` replacement) for the
//! `cargo bench` targets (`harness = false`).
//!
//! Methodology: warm-up runs, then timed iterations until both a minimum
//! iteration count and a minimum total measuring time are reached;
//! reports mean/σ/p50/p95 per iteration. Deliberately simple — the
//! numbers that matter for the paper figures come from the simulated
//! clock; wall-clock benches cover the *real* hot paths (structure ops,
//! router, PJRT execute).

use std::time::{Duration, Instant};

use super::stats::Summary;

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup_iters: u32,
    pub min_iters: u32,
    pub min_time: Duration,
    pub max_iters: u32,
}

impl Default for BenchConfig {
    fn default() -> BenchConfig {
        BenchConfig {
            warmup_iters: 3,
            min_iters: 10,
            min_time: Duration::from_millis(200),
            max_iters: 10_000,
        }
    }
}

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time summary (µs).
    pub summary: Summary,
    pub iters: u32,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.summary.mean
    }
}

/// A named collection of results, renderable as a markdown table.
#[derive(Debug, Default)]
pub struct BenchSuite {
    pub title: String,
    pub results: Vec<BenchResult>,
    cfg: BenchConfig,
}

impl BenchSuite {
    pub fn new(title: &str) -> BenchSuite {
        BenchSuite { title: title.to_string(), results: Vec::new(), cfg: BenchConfig::default() }
    }

    pub fn with_config(mut self, cfg: BenchConfig) -> BenchSuite {
        self.cfg = cfg;
        self
    }

    /// Run one benchmark: `f` is a full iteration (setup outside).
    pub fn bench(&mut self, name: &str, mut f: impl FnMut()) -> &BenchResult {
        for _ in 0..self.cfg.warmup_iters {
            f();
        }
        let mut samples: Vec<f64> = Vec::new();
        let start = Instant::now();
        let mut iters = 0u32;
        while (iters < self.cfg.min_iters || start.elapsed() < self.cfg.min_time) && iters < self.cfg.max_iters {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64() * 1e6);
            iters += 1;
        }
        let result = BenchResult { name: name.to_string(), summary: Summary::of(&samples), iters };
        eprintln!(
            "  {:<44} {:>12.2} µs/iter  (σ {:.2}, p95 {:.2}, n={})",
            result.name, result.summary.mean, result.summary.stddev, result.summary.p95, iters
        );
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Record externally-timed samples (µs per iteration) under the same
    /// reporting as [`BenchSuite::bench`] — for measurements whose
    /// setup/teardown cannot live inside a closure (e.g. service calls
    /// with untimed insert phases between timed seals).
    pub fn record_samples(&mut self, name: &str, samples: &[f64]) -> &BenchResult {
        let result = BenchResult {
            name: name.to_string(),
            summary: Summary::of(samples),
            iters: samples.len() as u32,
        };
        eprintln!(
            "  {:<44} {:>12.2} µs/iter  (σ {:.2}, p95 {:.2}, n={})",
            result.name, result.summary.mean, result.summary.stddev, result.summary.p95, result.iters
        );
        self.results.push(result);
        self.results.last().unwrap()
    }

    /// Record an externally-computed (e.g. simulated) value so it shows up
    /// in the same table.
    pub fn record(&mut self, name: &str, value_us: f64) {
        eprintln!("  {:<44} {:>12.2} µs (modeled)", name, value_us);
        self.results.push(BenchResult {
            name: name.to_string(),
            summary: Summary::of(&[value_us]),
            iters: 0,
        });
    }

    /// Markdown rendering for EXPERIMENTS.md.
    pub fn markdown(&self) -> String {
        let mut t = crate::util::csv::CsvTable::new(["benchmark", "mean_us", "stddev_us", "p95_us", "iters"]);
        for r in &self.results {
            t.push_display([
                r.name.clone(),
                format!("{:.2}", r.summary.mean),
                format!("{:.2}", r.summary.stddev),
                format!("{:.2}", r.summary.p95),
                r.iters.to_string(),
            ]);
        }
        format!("### {}\n\n{}", self.title, crate::util::tables::markdown(&t))
    }

    /// Print the header; call once at the top of a bench main.
    pub fn banner(&self) {
        eprintln!("\n== {} ==", self.title);
    }
}

/// Prevent the optimiser from discarding a value (ports of
/// `criterion::black_box` — `std::hint::black_box` is stable, use it).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut suite = BenchSuite::new("unit").with_config(BenchConfig {
            warmup_iters: 1,
            min_iters: 5,
            min_time: Duration::from_millis(1),
            max_iters: 50,
        });
        let mut acc = 0u64;
        suite.bench("count_to_1000", || {
            for i in 0..1000u64 {
                acc = black_box(acc.wrapping_add(i));
            }
        });
        let r = &suite.results[0];
        assert!(r.iters >= 5);
        assert!(r.summary.mean > 0.0);
        let md = suite.markdown();
        assert!(md.contains("count_to_1000"));
    }

    #[test]
    fn record_modeled_values() {
        let mut suite = BenchSuite::new("modeled");
        suite.record("table2_static_insert", 7070.0);
        assert_eq!(suite.results[0].summary.mean, 7070.0);
        assert_eq!(suite.results[0].iters, 0);
    }

    #[test]
    fn record_samples_summarises_external_timings() {
        let mut suite = BenchSuite::new("external");
        let r = suite.record_samples("seal", &[10.0, 20.0, 30.0]);
        assert!((r.mean_us() - 20.0).abs() < 1e-12);
        assert_eq!(r.iters, 3);
        assert!(suite.markdown().contains("seal"));
    }
}
