//! Machine-readable bench report schemas (the `BENCH_*.json` baselines
//! at the repo root): typed row structs, report builders and field
//! accessors shared by the bench binaries and their regression gates.
//!
//! The point of centralising this: the JSON nesting a gate *reads* is
//! produced by the same code the bench *writes*, and the round trip
//! (build → serialize → parse → extract gate fields) is unit-tested
//! here once instead of being desk-checked in every bench binary.

use std::collections::BTreeMap;

use super::json::Json;

/// Schema tag of `BENCH_hotpath.json` (see `benches/bench_hotpath.rs`).
/// v3 adds the skewed-routing columns (`skewed_insert_dispatch_us`,
/// `skewed_insert_serial_us`, `speedup.skewed_insert_4v1`) — the
/// work-stealing scheduler's payoff case; v2 baselines measured the
/// fork/join pool and are re-baselined.
pub const HOTPATH_SCHEMA: &str = "bench_hotpath/v3";
/// Schema tag of `BENCH_frontend.json` (see `benches/bench_frontend.rs`).
pub const FRONTEND_SCHEMA: &str = "bench_frontend/v1";

/// One `shards.<n>` row of the hotpath report.
#[derive(Debug, Clone)]
pub struct HotpathShardRow {
    pub shards: usize,
    /// Median wall µs of one large-batch insert dispatch.
    pub insert_dispatch_us: f64,
    /// Same dispatch forced through the serial loop at this shard count
    /// — only recorded for multi-shard rows (the 1-shard dispatch *is*
    /// serial), `None` omits the field from the JSON.
    pub insert_dispatch_serial_us: Option<f64>,
    /// Skewed-routing dispatch (one hot shard holding 3/4 of every
    /// batch) through the scheduler — only measured on the multi-shard
    /// row, `None` omits the field from the JSON.
    pub skewed_insert_dispatch_us: Option<f64>,
    /// The same skewed dispatch through the serial loop (the fork/join
    /// bound's reference numerator), `None` omits the field.
    pub skewed_insert_serial_us: Option<f64>,
    pub seal_us: f64,
    pub seal_us_median: f64,
    pub sealed_query_1k_us: f64,
}

impl HotpathShardRow {
    fn to_json(&self) -> Json {
        let mut fields = vec![
            ("insert_dispatch_us", Json::num(self.insert_dispatch_us)),
            ("seal_us", Json::num(self.seal_us)),
            ("seal_us_median", Json::num(self.seal_us_median)),
            ("sealed_query_1k_us", Json::num(self.sealed_query_1k_us)),
        ];
        if let Some(serial) = self.insert_dispatch_serial_us {
            fields.push(("insert_dispatch_serial_us", Json::num(serial)));
        }
        if let Some(skewed) = self.skewed_insert_dispatch_us {
            fields.push(("skewed_insert_dispatch_us", Json::num(skewed)));
        }
        if let Some(skewed_serial) = self.skewed_insert_serial_us {
            fields.push(("skewed_insert_serial_us", Json::num(skewed_serial)));
        }
        Json::obj(fields)
    }
}

/// The hotpath report's `speedup` section (absolute-gate inputs).
#[derive(Debug, Clone)]
pub struct HotpathSpeedup {
    pub batch_elements: usize,
    pub insert_dispatch_large_batch_4v1: f64,
    /// Skewed (3/4-hot-shard) dispatch speedup, scheduled vs serial on
    /// the identical routing — the fork/join pool was bounded at 4/3×
    /// here, the work-stealing gate requires beating that.
    pub skewed_insert_4v1: f64,
    pub seal_4v1: f64,
}

/// Assemble a `bench_hotpath/v3` report (rows keyed by shard count:
/// `"1"`, `"4"`, …).
pub fn hotpath_report(
    smoke: bool,
    elements: usize,
    rows: &[HotpathShardRow],
    speedup: &HotpathSpeedup,
) -> Json {
    let shards: BTreeMap<String, Json> =
        rows.iter().map(|r| (r.shards.to_string(), r.to_json())).collect();
    Json::obj(vec![
        ("schema", Json::str(HOTPATH_SCHEMA)),
        ("smoke", Json::Bool(smoke)),
        ("elements", Json::num(elements as f64)),
        ("shards", Json::Obj(shards)),
        (
            "speedup",
            Json::obj(vec![
                ("batch_elements", Json::num(speedup.batch_elements as f64)),
                (
                    "insert_dispatch_large_batch_4v1",
                    Json::num(speedup.insert_dispatch_large_batch_4v1),
                ),
                ("skewed_insert_4v1", Json::num(speedup.skewed_insert_4v1)),
                ("seal_4v1", Json::num(speedup.seal_4v1)),
            ]),
        ),
    ])
}

/// One `clients.<n>` row of the frontend report.
#[derive(Debug, Clone)]
pub struct FrontendClientRow {
    pub clients: usize,
    /// Sustained admitted requests per second, seal barrier included.
    pub req_per_s: f64,
    /// Per-request admission latency (µs): mean / p50 / p99 across all
    /// client threads, retries included.
    pub mean_us: f64,
    pub p50_us: f64,
    pub p99_us: f64,
    /// Typed rejections observed by the clients at this level.
    pub shed: u64,
}

/// Assemble a `bench_frontend/v1` report (rows keyed by client count).
pub fn frontend_report(
    smoke: bool,
    values_per_request: usize,
    total_values: u64,
    rows: &[FrontendClientRow],
) -> Json {
    let clients: BTreeMap<String, Json> = rows
        .iter()
        .map(|r| {
            (
                r.clients.to_string(),
                Json::obj(vec![
                    ("req_per_s", Json::num(r.req_per_s)),
                    ("mean_us", Json::num(r.mean_us)),
                    ("p50_us", Json::num(r.p50_us)),
                    ("p99_us", Json::num(r.p99_us)),
                    ("shed", Json::num(r.shed as f64)),
                ]),
            )
        })
        .collect();
    Json::obj(vec![
        ("schema", Json::str(FRONTEND_SCHEMA)),
        ("smoke", Json::Bool(smoke)),
        ("values_per_request", Json::num(values_per_request as f64)),
        ("total_values", Json::num(total_values as f64)),
        ("clients", Json::Obj(clients)),
    ])
}

/// The report's schema tag (`None` on malformed reports).
pub fn schema_of(report: &Json) -> Option<&str> {
    report.get("schema").and_then(Json::as_str)
}

/// `shards.<shards>.<field>` of a hotpath report — the accessor the
/// regression gate uses on baseline and fresh alike.
pub fn shard_field(report: &Json, shards: &str, field: &str) -> Option<f64> {
    report.get("shards").and_then(|s| s.get(shards)).and_then(|s| s.get(field)).and_then(Json::as_f64)
}

/// `speedup.<field>` of a hotpath report (absolute-gate input).
pub fn speedup_field(report: &Json, field: &str) -> Option<f64> {
    report.get("speedup").and_then(|s| s.get(field)).and_then(Json::as_f64)
}

/// `clients.<clients>.<field>` of a frontend report.
pub fn client_field(report: &Json, clients: &str, field: &str) -> Option<f64> {
    report.get("clients").and_then(|c| c.get(clients)).and_then(|c| c.get(field)).and_then(Json::as_f64)
}

#[cfg(test)]
mod tests {
    use super::super::json;
    use super::*;

    /// The CHANGES.md-flagged gap: the nesting was once desk-checked
    /// only. Build a populated report, serialize, re-parse, and assert
    /// every gate-relevant field survives the round trip.
    #[test]
    fn hotpath_v3_round_trips_gate_fields() {
        let rows = [
            HotpathShardRow {
                shards: 1,
                insert_dispatch_us: 812.25,
                insert_dispatch_serial_us: None,
                skewed_insert_dispatch_us: None,
                skewed_insert_serial_us: None,
                seal_us: 1900.5,
                seal_us_median: 1875.125,
                sealed_query_1k_us: 42.75,
            },
            HotpathShardRow {
                shards: 4,
                insert_dispatch_us: 310.5,
                insert_dispatch_serial_us: Some(905.25),
                skewed_insert_dispatch_us: Some(402.125),
                skewed_insert_serial_us: Some(880.5),
                seal_us: 760.75,
                seal_us_median: 741.5,
                sealed_query_1k_us: 43.25,
            },
        ];
        let speedup = HotpathSpeedup {
            batch_elements: 1 << 20,
            insert_dispatch_large_batch_4v1: 2.615,
            skewed_insert_4v1: 2.19,
            seal_4v1: 2.53,
        };
        let report = hotpath_report(false, 1 << 22, &rows, &speedup);
        let parsed = json::parse(&report.to_string_pretty()).expect("self-produced JSON parses");
        assert_eq!(schema_of(&parsed), Some(HOTPATH_SCHEMA));
        assert_eq!(parsed.get("smoke").and_then(Json::as_bool), Some(false));
        // The three relative-gate tuples...
        assert_eq!(shard_field(&parsed, "1", "insert_dispatch_us"), Some(812.25));
        assert_eq!(shard_field(&parsed, "4", "insert_dispatch_us"), Some(310.5));
        assert_eq!(shard_field(&parsed, "4", "seal_us_median"), Some(741.5));
        // ...the skewed-routing regression column...
        assert_eq!(shard_field(&parsed, "4", "skewed_insert_dispatch_us"), Some(402.125));
        assert_eq!(shard_field(&parsed, "4", "skewed_insert_serial_us"), Some(880.5));
        // ...the absolute speedup gates...
        assert_eq!(speedup_field(&parsed, "insert_dispatch_large_batch_4v1"), Some(2.615));
        assert_eq!(speedup_field(&parsed, "skewed_insert_4v1"), Some(2.19));
        assert_eq!(speedup_field(&parsed, "seal_4v1"), Some(2.53));
        // ...and the per-mode columns only where they were measured.
        assert_eq!(shard_field(&parsed, "4", "insert_dispatch_serial_us"), Some(905.25));
        assert_eq!(shard_field(&parsed, "1", "insert_dispatch_serial_us"), None);
        assert_eq!(shard_field(&parsed, "1", "skewed_insert_dispatch_us"), None);
    }

    #[test]
    fn frontend_v1_round_trips_latency_fields() {
        let rows = [
            FrontendClientRow {
                clients: 1,
                req_per_s: 51_250.5,
                mean_us: 18.125,
                p50_us: 15.5,
                p99_us: 90.25,
                shed: 0,
            },
            FrontendClientRow {
                clients: 64,
                req_per_s: 310_000.75,
                mean_us: 205.5,
                p50_us: 180.25,
                p99_us: 1450.125,
                shed: 37,
            },
        ];
        let report = frontend_report(true, 256, 4_000_000, &rows);
        let parsed = json::parse(&report.to_string_pretty()).expect("self-produced JSON parses");
        assert_eq!(schema_of(&parsed), Some(FRONTEND_SCHEMA));
        assert_eq!(parsed.get("smoke").and_then(Json::as_bool), Some(true));
        assert_eq!(parsed.get("values_per_request").and_then(Json::as_f64), Some(256.0));
        assert_eq!(parsed.get("total_values").and_then(Json::as_f64), Some(4_000_000.0));
        assert_eq!(client_field(&parsed, "1", "req_per_s"), Some(51_250.5));
        assert_eq!(client_field(&parsed, "1", "p50_us"), Some(15.5));
        assert_eq!(client_field(&parsed, "64", "p99_us"), Some(1450.125));
        assert_eq!(client_field(&parsed, "64", "shed"), Some(37.0));
        // Unknown rows/fields read as None, not panics — the gate's
        // missing-baseline path.
        assert_eq!(client_field(&parsed, "8", "req_per_s"), None);
        assert_eq!(client_field(&parsed, "64", "nope"), None);
    }

    #[test]
    fn schema_mismatch_is_detectable() {
        let report = frontend_report(false, 64, 1000, &[]);
        assert_ne!(schema_of(&report), Some(HOTPATH_SCHEMA));
        assert_eq!(shard_field(&report, "1", "insert_dispatch_us"), None);
    }
}
