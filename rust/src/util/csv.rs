//! Tiny CSV writer used by the experiment reports (`reports/*.csv`).
//! Quoting follows RFC 4180: fields containing commas, quotes or newlines
//! are quoted, with embedded quotes doubled.

use std::io::Write;
use std::path::Path;

/// An in-memory CSV table with a fixed header.
#[derive(Debug, Clone)]
pub struct CsvTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvTable {
    pub fn new<S: Into<String>, I: IntoIterator<Item = S>>(header: I) -> CsvTable {
        CsvTable { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn header(&self) -> &[String] {
        &self.header
    }

    /// Add a row; must match the header width.
    pub fn push<S: Into<String>, I: IntoIterator<Item = S>>(&mut self, row: I) {
        let row: Vec<String> = row.into_iter().map(Into::into).collect();
        assert_eq!(
            row.len(),
            self.header.len(),
            "csv row width {} != header width {}",
            row.len(),
            self.header.len()
        );
        self.rows.push(row);
    }

    /// Convenience: push a row of display-able values.
    pub fn push_display<D: std::fmt::Display, I: IntoIterator<Item = D>>(&mut self, row: I) {
        self.push(row.into_iter().map(|d| d.to_string()));
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        write_record(&mut out, &self.header);
        for row in &self.rows {
            write_record(&mut out, row);
        }
        out
    }

    /// Write to a file, creating parent directories.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_string().as_bytes())
    }

    /// Iterate rows (for tests and markdown rendering).
    pub fn rows(&self) -> &[Vec<String>] {
        &self.rows
    }
}

fn write_record(out: &mut String, fields: &[String]) {
    for (i, f) in fields.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if f.contains(',') || f.contains('"') || f.contains('\n') {
            out.push('"');
            out.push_str(&f.replace('"', "\"\""));
            out.push('"');
        } else {
            out.push_str(f);
        }
    }
    out.push('\n');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_table() {
        let mut t = CsvTable::new(["sigma", "static", "ggarray"]);
        t.push(["0.5", "1.2", "1.9"]);
        t.push_display([1.0, 10.24, 2.0]);
        assert_eq!(t.len(), 2);
        let s = t.to_string();
        assert_eq!(s, "sigma,static,ggarray\n0.5,1.2,1.9\n1,10.24,2\n");
    }

    #[test]
    fn quoting() {
        let mut t = CsvTable::new(["a"]);
        t.push(["hello, \"world\"\nbye"]);
        assert_eq!(t.to_string(), "a\n\"hello, \"\"world\"\"\nbye\"\n");
    }

    #[test]
    #[should_panic]
    fn width_mismatch_panics() {
        let mut t = CsvTable::new(["a", "b"]);
        t.push(["only-one"]);
    }

    #[test]
    fn save_creates_dirs() {
        let dir = std::env::temp_dir().join("ggarray_csv_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("nested/out.csv");
        let mut t = CsvTable::new(["x"]);
        t.push(["1"]);
        t.save(&path).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "x\n1\n");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
