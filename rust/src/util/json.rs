//! Minimal JSON (offline `serde_json` replacement): a value model, a
//! recursive-descent parser and a writer. Used for the AOT artifact
//! manifest (`artifacts/manifest.json`) and machine-readable experiment
//! reports. Supports the full JSON grammar except exotic number forms
//! beyond f64, which the manifest never uses.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use a BTreeMap so output is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors ----
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<T: Into<f64>>(x: T) -> Json {
        Json::Num(x.into())
    }

    pub fn str<S: Into<String>>(s: S) -> Json {
        Json::Str(s.into())
    }

    // ---- accessors ----
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| if f >= 0.0 && f.fract() == 0.0 { Some(f as u64) } else { None })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    // ---- serialisation ----
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(0));
        s.push('\n');
        s
    }

    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(level) = indent {
                        out.push('\n');
                        out.push_str(&"  ".repeat(level + 1));
                        item.write(out, Some(level + 1));
                    } else {
                        item.write(out, None);
                    }
                }
                if let Some(level) = indent {
                    out.push('\n');
                    out.push_str(&"  ".repeat(level));
                }
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(level) = indent {
                        out.push('\n');
                        out.push_str(&"  ".repeat(level + 1));
                        write_escaped(out, k);
                        out.push_str(": ");
                        v.write(out, Some(level + 1));
                    } else {
                        write_escaped(out, k);
                        out.push(':');
                        v.write(out, None);
                    }
                }
                if let Some(level) = indent {
                    out.push('\n');
                    out.push_str(&"  ".repeat(level));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse a JSON document (must consume all non-whitespace input).
pub fn parse(input: &str) -> Result<Json, ParseError> {
    let bytes = input.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != bytes.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'u') => {
                            if self.i + 5 > self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are not needed by our manifests;
                            // map unpaired surrogates to the replacement char.
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 character.
                    let rest = &self.b[self.i..];
                    let ch_len = utf8_len(rest[0]);
                    let chunk = std::str::from_utf8(&rest[..ch_len.min(rest.len())])
                        .map_err(|_| self.err("invalid utf8"))?;
                    s.push_str(chunk);
                    self.i += chunk.len();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let v = Json::obj(vec![
            ("name", Json::str("scan_mxu")),
            ("sizes", Json::arr([Json::num(1024.0), Json::num(4096.0)])),
            ("interpret", Json::Bool(true)),
            ("extra", Json::Null),
        ]);
        let s = v.to_string_pretty();
        let back = parse(&s).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parse_numbers() {
        assert_eq!(parse("42").unwrap().as_f64(), Some(42.0));
        assert_eq!(parse("-3.5").unwrap().as_f64(), Some(-3.5));
        assert_eq!(parse("1e6").unwrap().as_f64(), Some(1e6));
        assert_eq!(parse("2.5E-2").unwrap().as_f64(), Some(0.025));
    }

    #[test]
    fn parse_strings_with_escapes() {
        let v = parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\ndA"));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, {"b": null}, true], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].get("b"), Some(&Json::Null));
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(Json::num(5.0).to_string_compact(), "5");
        assert_eq!(Json::num(5.25).to_string_compact(), "5.25");
    }

    #[test]
    fn unicode_passthrough() {
        let v = parse("\"λ→μ\"").unwrap();
        assert_eq!(v.as_str(), Some("λ→μ"));
        let s = v.to_string_compact();
        assert_eq!(parse(&s).unwrap(), v);
    }

    #[test]
    fn as_u64_guards() {
        assert_eq!(parse("7").unwrap().as_u64(), Some(7));
        assert_eq!(parse("7.5").unwrap().as_u64(), None);
        assert_eq!(parse("-7").unwrap().as_u64(), None);
    }
}
