//! Special functions needed by the theoretical memory model (§V / Fig 3):
//! `erf`, `erfc`, `erfinv`, normal and log-normal CDFs/quantiles.
//!
//! Implementations follow standard rational/polynomial approximations
//! (Abramowitz & Stegun 7.1.26 refined to double precision for `erf`;
//! Peter Acklam's algorithm for the normal quantile) and are validated in
//! the unit tests against high-precision reference values.

/// Error function `erf(x)` with absolute error < 1.5e-7 over all reals.
///
/// Uses the A&S 7.1.26 rational approximation on |x| combined with the odd
/// symmetry `erf(-x) = -erf(x)`.
pub fn erf(x: f64) -> f64 {
    // For large |x| the result saturates; cut off to avoid exp underflow.
    if x > 6.0 {
        return 1.0;
    }
    if x < -6.0 {
        return -1.0;
    }
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    // A&S 7.1.26 coefficients.
    const A1: f64 = 0.254829592;
    const A2: f64 = -0.284496736;
    const A3: f64 = 1.421413741;
    const A4: f64 = -1.453152027;
    const A5: f64 = 1.061405429;
    const P: f64 = 0.3275911;
    let t = 1.0 / (1.0 + P * x);
    let y = 1.0 - (((((A5 * t + A4) * t) + A3) * t + A2) * t + A1) * t * (-x * x).exp();
    sign * y
}

/// Complementary error function `erfc(x) = 1 - erf(x)`.
pub fn erfc(x: f64) -> f64 {
    1.0 - erf(x)
}

/// Standard normal CDF Φ(x).
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Standard normal quantile Φ⁻¹(p) (a.k.a. probit), p ∈ (0, 1).
///
/// Peter Acklam's rational approximation (relative error < 1.15e-9),
/// followed by one step of Halley refinement using [`norm_cdf`], which
/// pushes the error to ~1e-12 across the useful range.
pub fn norm_quantile(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "norm_quantile requires p in (0,1), got {p}");
    // Coefficients for the central and tail rational approximations.
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;
    let x = if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    };
    // One Halley step: x' = x - f/(f' - f·f''/(2f')) with f = Φ(x) - p.
    let e = norm_cdf(x) - p;
    let u = e * (2.0 * std::f64::consts::PI).sqrt() * (x * x / 2.0).exp();
    x - u / (1.0 + x * u / 2.0)
}

/// Inverse error function, via the probit: `erfinv(y) = Φ⁻¹((y+1)/2)/√2`.
pub fn erfinv(y: f64) -> f64 {
    assert!(y > -1.0 && y < 1.0, "erfinv requires y in (-1,1), got {y}");
    norm_quantile((y + 1.0) / 2.0) / std::f64::consts::SQRT_2
}

/// CDF of LogNormal(mu, sigma) at x > 0.
pub fn lognormal_cdf(x: f64, mu: f64, sigma: f64) -> f64 {
    if x <= 0.0 {
        return 0.0;
    }
    norm_cdf((x.ln() - mu) / sigma)
}

/// Quantile of LogNormal(mu, sigma): `exp(mu + sigma·Φ⁻¹(p))`.
pub fn lognormal_quantile(p: f64, mu: f64, sigma: f64) -> f64 {
    if sigma == 0.0 {
        return mu.exp();
    }
    (mu + sigma * norm_quantile(p)).exp()
}

/// Mean of LogNormal(mu, sigma): `exp(mu + sigma²/2)`.
pub fn lognormal_mean(mu: f64, sigma: f64) -> f64 {
    (mu + sigma * sigma / 2.0).exp()
}

/// Next power of two ≥ `x` (x ≥ 1). `next_pow2(0) == 1`.
pub fn next_pow2(x: u64) -> u64 {
    if x <= 1 {
        1
    } else {
        1u64 << (64 - (x - 1).leading_zeros())
    }
}

/// Integer ceil division.
pub fn ceil_div(a: u64, b: u64) -> u64 {
    assert!(b > 0);
    (a + b - 1) / b
}

/// floor(log2(x)) for x ≥ 1.
pub fn ilog2(x: u64) -> u32 {
    assert!(x >= 1);
    63 - x.leading_zeros()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_reference_values() {
        // Reference values from standard tables.
        let cases = [
            (0.0, 0.0),
            (0.5, 0.5204998778),
            (1.0, 0.8427007929),
            (2.0, 0.9953222650),
            (3.0, 0.9999779095),
            (-1.0, -0.8427007929),
        ];
        for (x, want) in cases {
            assert!((erf(x) - want).abs() < 2e-7, "erf({x}) = {} want {want}", erf(x));
        }
    }

    #[test]
    fn erf_saturates() {
        assert_eq!(erf(10.0), 1.0);
        assert_eq!(erf(-10.0), -1.0);
    }

    #[test]
    fn norm_cdf_symmetry() {
        for &x in &[0.1, 0.7, 1.3, 2.9] {
            assert!((norm_cdf(x) + norm_cdf(-x) - 1.0).abs() < 1e-9);
        }
        // A&S 7.1.26 has ~1e-9 absolute error at 0 (coefficients don't sum
        // exactly to 1).
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-8);
    }

    #[test]
    fn norm_quantile_roundtrip() {
        for &p in &[0.001, 0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 0.999] {
            let x = norm_quantile(p);
            assert!((norm_cdf(x) - p).abs() < 1e-6, "p={p} x={x} cdf={}", norm_cdf(x));
        }
    }

    #[test]
    fn norm_quantile_reference() {
        // Φ⁻¹(0.99) = 2.3263478740, Φ⁻¹(0.975) = 1.9599639845
        assert!((norm_quantile(0.99) - 2.3263478740).abs() < 1e-5);
        assert!((norm_quantile(0.975) - 1.9599639845).abs() < 1e-5);
    }

    #[test]
    #[should_panic]
    fn norm_quantile_rejects_zero() {
        norm_quantile(0.0);
    }

    #[test]
    fn erfinv_roundtrip() {
        for &y in &[-0.9, -0.5, -0.1, 0.0 + 1e-12, 0.1, 0.5, 0.9, 0.99] {
            let x = erfinv(y);
            assert!((erf(x) - y).abs() < 1e-6, "y={y} erf(erfinv(y))={}", erf(x));
        }
    }

    #[test]
    fn lognormal_quantile_matches_paper_example() {
        // Static array provisioned for 1% failure = q99 of LogNormal(0, σ).
        // σ=1 → e^{2.3263} ≈ 10.24 ; σ=2 → e^{4.6527} ≈ 104.9
        assert!((lognormal_quantile(0.99, 0.0, 1.0) - 10.240).abs() < 0.01);
        assert!((lognormal_quantile(0.99, 0.0, 2.0) - 104.86).abs() < 0.2);
        // σ=0 degenerates to exp(mu).
        assert_eq!(lognormal_quantile(0.99, 0.0, 0.0), 1.0);
    }

    #[test]
    fn lognormal_cdf_quantile_inverse() {
        for &p in &[0.05, 0.5, 0.95] {
            for &s in &[0.3, 1.0, 2.0] {
                let x = lognormal_quantile(p, 0.0, s);
                assert!((lognormal_cdf(x, 0.0, s) - p).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn lognormal_mean_value() {
        assert!((lognormal_mean(0.0, 1.0) - (0.5f64).exp()).abs() < 1e-12);
    }

    #[test]
    fn pow2_helpers() {
        assert_eq!(next_pow2(0), 1);
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(2), 2);
        assert_eq!(next_pow2(3), 4);
        assert_eq!(next_pow2(1023), 1024);
        assert_eq!(next_pow2(1024), 1024);
        assert_eq!(next_pow2(1025), 2048);
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ilog2(1), 0);
        assert_eq!(ilog2(1024), 10);
        assert_eq!(ilog2(1025), 10);
    }
}
