//! From-scratch utility substrate.
//!
//! This environment is offline: only the vendored dependency closure of the
//! `xla` crate is available, so the usual ecosystem crates (clap, serde,
//! rand, criterion, proptest) are re-implemented here as small focused
//! modules. Each is a real, tested implementation — not a stub — sized to
//! what the rest of the system needs.

pub mod argparse;
pub mod benchkit;
pub mod benchreport;
pub mod csv;
pub mod json;
pub mod math;
pub mod plot;
pub mod rng;
pub mod stats;
pub mod tables;
