//! Terminal line plots for the figure CLI: renders (x, y) series as an
//! ASCII chart with log-scale support, so `repro fig3 --plot` shows the
//! figure's shape without leaving the terminal.

/// One named series.
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>,
}

/// Plot configuration.
#[derive(Debug, Clone)]
pub struct PlotConfig {
    pub width: usize,
    pub height: usize,
    pub log_y: bool,
    pub log_x: bool,
    pub title: String,
}

impl Default for PlotConfig {
    fn default() -> PlotConfig {
        PlotConfig { width: 72, height: 20, log_y: false, log_x: false, title: String::new() }
    }
}

const MARKS: [char; 6] = ['*', 'o', '+', 'x', '#', '@'];

fn transform(v: f64, log: bool) -> f64 {
    if log {
        v.max(1e-12).log10()
    } else {
        v
    }
}

/// Render the series into an ASCII chart.
pub fn render(series: &[Series], cfg: &PlotConfig) -> String {
    let pts: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|s| s.points.iter().map(|&(x, y)| (transform(x, cfg.log_x), transform(y, cfg.log_y))))
        .filter(|(x, y)| x.is_finite() && y.is_finite())
        .collect();
    if pts.is_empty() {
        return "(no data)\n".to_string();
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &pts {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if (x1 - x0).abs() < 1e-12 {
        x1 = x0 + 1.0;
    }
    if (y1 - y0).abs() < 1e-12 {
        y1 = y0 + 1.0;
    }
    let mut grid = vec![vec![' '; cfg.width]; cfg.height];
    for (si, s) in series.iter().enumerate() {
        let mark = MARKS[si % MARKS.len()];
        for &(x, y) in &s.points {
            let (tx, ty) = (transform(x, cfg.log_x), transform(y, cfg.log_y));
            if !tx.is_finite() || !ty.is_finite() {
                continue;
            }
            let col = (((tx - x0) / (x1 - x0)) * (cfg.width - 1) as f64).round() as usize;
            let row = (((ty - y0) / (y1 - y0)) * (cfg.height - 1) as f64).round() as usize;
            let r = cfg.height - 1 - row.min(cfg.height - 1);
            grid[r][col.min(cfg.width - 1)] = mark;
        }
    }
    let untransform = |v: f64, log: bool| if log { 10f64.powf(v) } else { v };
    let mut out = String::new();
    if !cfg.title.is_empty() {
        out.push_str(&format!("  {}\n", cfg.title));
    }
    let ylab = |v: f64| format!("{:>9.3}", untransform(v, cfg.log_y));
    for (r, row) in grid.iter().enumerate() {
        let frac = 1.0 - r as f64 / (cfg.height - 1) as f64;
        let label = if r == 0 || r == cfg.height - 1 || r == cfg.height / 2 {
            ylab(y0 + frac * (y1 - y0))
        } else {
            " ".repeat(9)
        };
        out.push_str(&format!("{label} |{}\n", row.iter().collect::<String>()));
    }
    out.push_str(&format!("{} +{}\n", " ".repeat(9), "-".repeat(cfg.width)));
    out.push_str(&format!(
        "{}  {:<.3}{}{:>.3}\n",
        " ".repeat(9),
        untransform(x0, cfg.log_x),
        " ".repeat(cfg.width.saturating_sub(12)),
        untransform(x1, cfg.log_x)
    ));
    out.push_str("  legend: ");
    for (si, s) in series.iter().enumerate() {
        out.push_str(&format!("{} {}  ", MARKS[si % MARKS.len()], s.name));
    }
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn curve(name: &str, f: impl Fn(f64) -> f64) -> Series {
        Series { name: name.into(), points: (0..=20).map(|i| (i as f64 / 10.0, f(i as f64 / 10.0))).collect() }
    }

    #[test]
    fn renders_basic_shape() {
        let s = vec![curve("up", |x| x), curve("down", |x| 2.0 - x)];
        let out = render(&s, &PlotConfig { title: "cross".into(), ..PlotConfig::default() });
        assert!(out.contains("cross"));
        assert!(out.contains("legend: * up  o down"));
        // Rising series: '*' appears in the top row within the right half.
        let top = out.lines().nth(1).unwrap();
        let pos = top.rfind('*').unwrap();
        assert!(pos > top.len() / 2, "{out}");
    }

    #[test]
    fn log_scale_compresses() {
        let s = vec![Series { name: "exp".into(), points: (0..=10).map(|i| (i as f64, 10f64.powi(i))).collect() }];
        let lin = render(&s, &PlotConfig::default());
        let log = render(&s, &PlotConfig { log_y: true, ..PlotConfig::default() });
        // On a log axis the exponential becomes a diagonal: the middle
        // band (rows 8–12 of 20) must contain marks; on a linear axis all
        // but the largest point collapse onto the bottom rows.
        let mid_band_has = |s: &str, lo: usize, hi: usize| {
            s.lines().skip(lo).take(hi - lo).any(|l| l.contains('*'))
        };
        assert!(mid_band_has(&log, 8, 13), "{log}");
        assert!(!mid_band_has(&lin, 5, 15), "{lin}");
    }

    #[test]
    fn empty_and_degenerate() {
        assert_eq!(render(&[], &PlotConfig::default()), "(no data)\n");
        let flat = vec![Series { name: "flat".into(), points: vec![(1.0, 5.0), (2.0, 5.0)] }];
        let out = render(&flat, &PlotConfig::default());
        assert!(out.contains('*'));
    }
}
