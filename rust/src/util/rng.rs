//! Deterministic pseudo-random number generation (offline `rand`
//! replacement): SplitMix64 seeding + xoshiro256** core, plus the
//! distributions the workload generators need (uniform, Bernoulli, normal
//! via Box–Muller, log-normal, and shuffling).
//!
//! All experiment randomness flows through this module with explicit seeds
//! so every figure/table is exactly reproducible.

/// SplitMix64 step — used to expand a single `u64` seed into the xoshiro
/// state (the construction recommended by the xoshiro authors).
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG. Fast, high quality, 2^256-1 period.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal variate from Box–Muller.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, gauss_spare: None }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next u32.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform u64 in [0, n) without modulo bias (Lemire's method would be
    /// faster; rejection sampling is simpler and this is not a hot path).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform usize in [lo, hi) — hi exclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo, "empty range [{lo},{hi})");
        lo + self.below(hi - lo)
    }

    /// Bernoulli(p).
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (caches the spare variate).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Avoid ln(0).
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal(mu, sigma).
    pub fn normal(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.gaussian()
    }

    /// LogNormal(mu, sigma) — the paper's Fig 3 growth-factor distribution.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal(mu, sigma).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        if xs.len() < 2 {
            return;
        }
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (k ≤ n), order unspecified.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // Floyd's algorithm.
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j as u64 + 1) as usize;
            if chosen.insert(t) {
                out.push(t);
            } else {
                chosen.insert(j);
                out.push(j);
            }
        }
        out
    }

    /// Fork a child generator (independent stream) — used to give each
    /// simulated thread block its own stream.
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close_to_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_respects_bound_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(99);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn lognormal_median_is_exp_mu() {
        let mut r = Rng::new(5);
        let n = 100_001;
        let mut xs: Vec<f64> = (0..n).map(|_| r.lognormal(0.0, 1.0)).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = xs[n / 2];
        assert!((median - 1.0).abs() < 0.05, "median {median}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(23);
        let idx = r.sample_indices(50, 20);
        assert_eq!(idx.len(), 20);
        let set: std::collections::HashSet<_> = idx.iter().collect();
        assert_eq!(set.len(), 20);
        assert!(idx.iter().all(|&i| i < 50));
    }

    #[test]
    fn fork_streams_independent() {
        let mut parent = Rng::new(1);
        let mut c1 = parent.fork();
        let mut c2 = parent.fork();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }
}
