//! Descriptive statistics over benchmark samples and Monte-Carlo draws:
//! mean / variance (Welford), percentiles, and a compact [`Summary`] used
//! by the bench harness and the experiment reports.

/// Streaming mean/variance accumulator (Welford's algorithm).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Sample variance (n−1 denominator).
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile with linear interpolation (type-7, the numpy default).
/// `q` in [0, 100]. Sorts a copy; fine for bench-sized sample sets.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "percentile of empty slice");
    assert!((0.0..=100.0).contains(&q));
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, q)
}

/// Percentile over an already-sorted slice.
pub fn percentile_sorted(v: &[f64], q: f64) -> f64 {
    let n = v.len();
    if n == 1 {
        return v[0];
    }
    let pos = q / 100.0 * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    v[lo] + (v[hi] - v[lo]) * frac
}

/// Compact distribution summary of a sample set.
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty());
        let mut w = Welford::new();
        for &x in xs {
            w.push(x);
        }
        let mut v = xs.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n: xs.len(),
            mean: w.mean(),
            stddev: w.stddev(),
            min: w.min(),
            p50: percentile_sorted(&v, 50.0),
            p95: percentile_sorted(&v, 95.0),
            max: w.max(),
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.4} σ={:.4} min={:.4} p50={:.4} p95={:.4} max={:.4}",
            self.n, self.mean, self.stddev, self.min, self.p50, self.p95, self.max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_naive() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert_eq!(w.count(), 8);
        assert!((w.mean() - 5.0).abs() < 1e-12);
        // population var = 4, sample var = 32/7
        assert!((w.variance() - 32.0 / 7.0).abs() < 1e-12);
        assert_eq!(w.min(), 2.0);
        assert_eq!(w.max(), 9.0);
    }

    #[test]
    fn welford_empty_and_single() {
        let w = Welford::new();
        assert!(w.mean().is_nan());
        assert_eq!(w.variance(), 0.0);
        let mut w = Welford::new();
        w.push(3.5);
        assert_eq!(w.mean(), 3.5);
        assert_eq!(w.variance(), 0.0);
    }

    #[test]
    fn percentile_linear_interp() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 25.0) - 1.75).abs() < 1e-12);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [9.0, 1.0, 5.0];
        assert_eq!(percentile(&xs, 50.0), 5.0);
    }

    #[test]
    fn summary_fields() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 100);
        assert!((s.mean - 50.5).abs() < 1e-12);
        assert!((s.p50 - 50.5).abs() < 1e-9);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert!(s.p95 > 94.0 && s.p95 < 96.1);
    }
}
