//! Markdown table rendering for terminal output and EXPERIMENTS.md
//! snippets. Columns are auto-sized; numeric-looking cells are
//! right-aligned.

use super::csv::CsvTable;

/// Render a [`CsvTable`] as a GitHub-flavored markdown table.
pub fn markdown(table: &CsvTable) -> String {
    let header = table.header();
    let rows = table.rows();
    let ncols = header.len();
    let mut width = vec![0usize; ncols];
    let mut numeric = vec![true; ncols];
    for (c, h) in header.iter().enumerate() {
        width[c] = width[c].max(display_width(h));
    }
    for row in rows {
        for (c, cell) in row.iter().enumerate() {
            width[c] = width[c].max(display_width(cell));
            if !cell.is_empty() && cell.parse::<f64>().is_err() && cell != "-" && cell != "_" {
                numeric[c] = false;
            }
        }
    }
    let mut out = String::new();
    render_row(&mut out, header, &width, &numeric);
    out.push('|');
    for c in 0..ncols {
        out.push_str(&"-".repeat(width[c] + 2));
        if numeric[c] {
            // Right-align marker.
            out.pop();
            out.push(':');
        }
        out.push('|');
    }
    out.push('\n');
    for row in rows {
        render_row(&mut out, row, &width, &numeric);
    }
    out
}

fn render_row<S: AsRef<str>>(out: &mut String, cells: &[S], width: &[usize], numeric: &[bool]) {
    out.push('|');
    for (c, cell) in cells.iter().enumerate() {
        let cell = cell.as_ref();
        let pad = width[c].saturating_sub(display_width(cell));
        out.push(' ');
        if numeric[c] {
            out.push_str(&" ".repeat(pad));
            out.push_str(cell);
        } else {
            out.push_str(cell);
            out.push_str(&" ".repeat(pad));
        }
        out.push_str(" |");
    }
    out.push('\n');
}

/// Approximate display width: count chars (we only use ASCII + a few Greek
/// letters in headers, all single-width).
fn display_width(s: &str) -> usize {
    s.chars().count()
}

/// Format milliseconds with sensible precision.
pub fn fmt_ms(ms: f64) -> String {
    if ms >= 100.0 {
        format!("{ms:.1}")
    } else if ms >= 1.0 {
        format!("{ms:.2}")
    } else {
        format!("{ms:.4}")
    }
}

/// Format a count with SI-style suffix (1.0e6 → "1.0M").
pub fn fmt_count(n: u64) -> String {
    let nf = n as f64;
    if nf >= 1e9 {
        format!("{:.3}G", nf / 1e9)
    } else if nf >= 1e6 {
        format!("{:.1}M", nf / 1e6)
    } else if nf >= 1e3 {
        format!("{:.1}K", nf / 1e3)
    } else {
        format!("{n}")
    }
}

/// Format bytes human-readably.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::csv::CsvTable;

    #[test]
    fn renders_alignment() {
        let mut t = CsvTable::new(["name", "ms"]);
        t.push(["static", "7.07"]);
        t.push(["GGArray512", "11.79"]);
        let md = markdown(&t);
        assert!(md.contains("| static     |"));
        assert!(md.contains("-:|"), "numeric col should right-align: {md}");
        assert!(md.lines().count() == 4);
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(fmt_ms(123.456), "123.5");
        assert_eq!(fmt_ms(7.071), "7.07");
        assert_eq!(fmt_ms(0.52149), "0.5215");
        assert_eq!(fmt_count(512), "512");
        assert_eq!(fmt_count(1_024_000_000), "1.024G");
        assert_eq!(fmt_count(5_000), "5.0K");
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2 * 1024 * 1024), "2.00 MiB");
    }
}
