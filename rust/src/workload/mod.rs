//! Workload generators for the experiments and the coordinator.
//!
//! The paper's workloads:
//! * **duplication** (Fig 4/5): start at 1e6, insert one element per
//!   existing element, 10 times;
//! * **uncertain growth** (Fig 3): total insertions = `s · LogNormal(0,σ)`;
//! * **two-phase** (Fig 6): repeat { insert `k·size` elements; run the
//!   work kernel `w` times } for 5 iterations ending at 1e9 elements.

pub mod trace;

use crate::util::rng::Rng;

/// A single step in a generated workload trace.
#[derive(Debug, Clone, PartialEq)]
pub enum Step {
    /// Insert this many elements (values synthesised by the driver).
    Insert(u64),
    /// Run the +1 work kernel this many times over the whole array.
    Work(u32),
    /// Flatten into a contiguous array (two-phase pattern).
    Flatten,
    /// Seal the current epoch: flatten every shard into the contiguous
    /// fast-access view and open a fresh insert epoch (sharded two-phase
    /// lifecycle; flat structures treat it as a no-op like `Flatten`).
    Seal,
}

/// Declarative description of a workload.
#[derive(Debug, Clone)]
pub struct WorkloadSpec {
    pub name: String,
    pub steps: Vec<Step>,
    /// Expected final element count (for validation).
    pub expected_final: u64,
}

impl WorkloadSpec {
    /// Fig 4/5 duplication: `iters` doublings from `start`.
    pub fn duplication(start: u64, iters: u32) -> WorkloadSpec {
        let mut steps = vec![Step::Insert(start)];
        let mut size = start;
        for _ in 0..iters {
            steps.push(Step::Insert(size));
            size *= 2;
        }
        WorkloadSpec { name: format!("duplication_{start}x{iters}"), steps, expected_final: size }
    }

    /// Fig 6 two-phase: `phases` iterations of insert(k·size) + work(w),
    /// sized so the final array is `final_size` regardless of `k`.
    ///
    /// Paper: "a starting array size such that after all iterations and
    /// independent of the amount of insertions per thread per iteration
    /// the final size is 1e9" — so `start = final / (k+1)^phases`.
    pub fn two_phase(final_size: u64, inserts_per_elem: u64, work_calls: u32, phases: u32) -> WorkloadSpec {
        let growth = (inserts_per_elem + 1).pow(phases);
        let start = (final_size / growth).max(1);
        let mut steps = vec![Step::Insert(start)];
        let mut size = start;
        for _ in 0..phases {
            let ins = size * inserts_per_elem;
            steps.push(Step::Insert(ins));
            size += ins;
            steps.push(Step::Flatten);
            steps.push(Step::Work(work_calls));
        }
        WorkloadSpec {
            name: format!("two_phase_f{final_size}_k{inserts_per_elem}_w{work_calls}"),
            steps,
            expected_final: size,
        }
    }

    /// Sharded two-phase lifecycle: like [`WorkloadSpec::two_phase`] but
    /// each phase *seals* its epoch instead of taking a throwaway flatten
    /// snapshot — inserts grow the shard GgArrays, the seal moves the
    /// epoch into the flat fast-access view, and the work phase runs at
    /// static-array cost over everything sealed so far.
    pub fn two_phase_sharded(
        final_size: u64,
        inserts_per_elem: u64,
        work_calls: u32,
        phases: u32,
    ) -> WorkloadSpec {
        let growth = (inserts_per_elem + 1).pow(phases);
        let start = (final_size / growth).max(1);
        let mut steps = vec![Step::Insert(start)];
        let mut size = start;
        for _ in 0..phases {
            let ins = size * inserts_per_elem;
            steps.push(Step::Insert(ins));
            size += ins;
            steps.push(Step::Seal);
            steps.push(Step::Work(work_calls));
        }
        WorkloadSpec {
            name: format!("two_phase_sharded_f{final_size}_k{inserts_per_elem}_w{work_calls}"),
            steps,
            expected_final: size,
        }
    }

    /// Epoch churn: `epochs` repeated insert→seal cycles of `per_epoch`
    /// elements each, then one work phase over the (fully sealed) store.
    /// This is the segment-hygiene stressor: without sealed-epoch
    /// compaction the flat store accumulates one segment per cycle.
    pub fn seal_cycles(per_epoch: u64, epochs: u32, work_calls: u32) -> WorkloadSpec {
        let mut steps = Vec::with_capacity(epochs as usize * 2 + 1);
        for _ in 0..epochs {
            steps.push(Step::Insert(per_epoch));
            steps.push(Step::Seal);
        }
        if work_calls > 0 {
            steps.push(Step::Work(work_calls));
        }
        WorkloadSpec {
            name: format!("seal_cycles_{per_epoch}x{epochs}_w{work_calls}"),
            steps,
            expected_final: per_epoch * epochs as u64,
        }
    }

    /// Fig 3 uncertain growth: one bulk insert of `s·X`, `X~LogNormal(0,σ)`.
    pub fn uncertain(s: u64, sigma: f64, rng: &mut Rng) -> WorkloadSpec {
        let x = if sigma == 0.0 { 1.0 } else { rng.lognormal(0.0, sigma) };
        let n = ((s as f64) * x).max(1.0) as u64;
        WorkloadSpec { name: format!("uncertain_s{s}_sigma{sigma}"), steps: vec![Step::Insert(n)], expected_final: n }
    }

    /// Total elements inserted over the trace.
    pub fn total_inserts(&self) -> u64 {
        self.steps
            .iter()
            .map(|s| match s {
                Step::Insert(n) => *n,
                _ => 0,
            })
            .sum()
    }
}

/// Synthesise deterministic element values for an insert step (the data
/// the experiments push through the structures; value = a simple mix of
/// the running counter so readback can be verified).
pub fn synth_values(start_counter: u64, n: usize) -> Vec<u32> {
    (0..n as u64).map(|i| ((start_counter + i).wrapping_mul(2654435761) >> 8) as u32).collect()
}

/// Deterministic f32 value for element `counter` of a coordinator-driven
/// workload. Kept within f32's exact-integer range (and away from its
/// upper end) so repeated +1 work passes stay bit-exact — the property
/// the cross-shard byte-identity tests rely on.
pub fn synth_f32(counter: u64) -> f32 {
    ((counter.wrapping_mul(2654435761) >> 12) % (1 << 22)) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duplication_trace() {
        let w = WorkloadSpec::duplication(1_000_000, 10);
        assert_eq!(w.steps.len(), 11);
        assert_eq!(w.expected_final, 1_024_000_000);
        assert_eq!(w.total_inserts(), 1_024_000_000);
        assert_eq!(w.steps[0], Step::Insert(1_000_000));
        assert_eq!(w.steps[10], Step::Insert(512_000_000));
    }

    #[test]
    fn two_phase_final_size_independent_of_k() {
        // Paper: final size 1e9 for k ∈ {1,3,10}, 5 phases.
        for k in [1u64, 3, 10] {
            let w = WorkloadSpec::two_phase(1_000_000_000, k, 100, 5);
            let rel = (w.expected_final as f64 - 1e9).abs() / 1e9;
            assert!(rel < 0.05, "k={k}: final {}", w.expected_final);
            // Each phase has insert + flatten + work.
            assert_eq!(w.steps.len(), 1 + 15);
        }
    }

    #[test]
    fn two_phase_sharded_mirrors_two_phase_with_seals() {
        let flat = WorkloadSpec::two_phase(1_000_000, 3, 10, 4);
        let sharded = WorkloadSpec::two_phase_sharded(1_000_000, 3, 10, 4);
        assert_eq!(sharded.expected_final, flat.expected_final);
        assert_eq!(sharded.total_inserts(), flat.total_inserts());
        assert_eq!(sharded.steps.len(), flat.steps.len());
        let seals = sharded.steps.iter().filter(|s| matches!(s, Step::Seal)).count();
        assert_eq!(seals, 4);
        assert!(!sharded.steps.iter().any(|s| matches!(s, Step::Flatten)));
    }

    #[test]
    fn seal_cycles_trace_shape() {
        let w = WorkloadSpec::seal_cycles(1000, 6, 2);
        assert_eq!(w.expected_final, 6000);
        assert_eq!(w.total_inserts(), 6000);
        let seals = w.steps.iter().filter(|s| matches!(s, Step::Seal)).count();
        assert_eq!(seals, 6);
        assert_eq!(w.steps.last(), Some(&Step::Work(2)));
        // Zero work calls → pure churn trace.
        let w0 = WorkloadSpec::seal_cycles(10, 2, 0);
        assert_eq!(w0.steps.len(), 4);
    }

    #[test]
    fn synth_f32_deterministic_and_exact() {
        for c in [0u64, 1, 1000, u64::MAX / 3] {
            let v = synth_f32(c);
            assert_eq!(v, synth_f32(c));
            assert!(v >= 0.0 && v < (1 << 22) as f32);
            assert_eq!(v.fract(), 0.0, "synth_f32 must be an exact integer value");
        }
    }

    #[test]
    fn uncertain_respects_sigma_zero() {
        let mut rng = Rng::new(5);
        let w = WorkloadSpec::uncertain(1000, 0.0, &mut rng);
        assert_eq!(w.expected_final, 1000);
    }

    #[test]
    fn synth_values_deterministic_and_spread() {
        let a = synth_values(0, 100);
        let b = synth_values(0, 100);
        assert_eq!(a, b);
        let uniq: std::collections::HashSet<_> = a.iter().collect();
        assert!(uniq.len() > 95);
        let c = synth_values(100, 1);
        assert_ne!(a[0], c[0]);
    }
}
