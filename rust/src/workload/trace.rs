//! Replayable workload traces: serialise a [`WorkloadSpec`] to a simple
//! line format, load it back, and drive structures from files — so
//! experiments can be re-run bit-for-bit and external traces can be fed
//! in.
//!
//! Format (one step per line, `#` comments):
//! ```text
//! # name: duplication_1000000x10
//! insert 1000000
//! work 30
//! flatten
//! ```

use std::path::Path;

use super::{Step, WorkloadSpec};

/// Serialise to the line format.
pub fn to_text(w: &WorkloadSpec) -> String {
    let mut s = format!("# name: {}\n# expected_final: {}\n", w.name, w.expected_final);
    for step in &w.steps {
        match step {
            Step::Insert(n) => s.push_str(&format!("insert {n}\n")),
            Step::Work(c) => s.push_str(&format!("work {c}\n")),
            Step::Flatten => s.push_str("flatten\n"),
            Step::Seal => s.push_str("seal\n"),
        }
    }
    s
}

/// Parse the line format.
pub fn from_text(text: &str) -> anyhow::Result<WorkloadSpec> {
    let mut name = "trace".to_string();
    let mut steps = Vec::new();
    let mut running = 0u64;
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('#') {
            if let Some(n) = rest.trim().strip_prefix("name:") {
                name = n.trim().to_string();
            }
            continue;
        }
        let mut parts = line.split_whitespace();
        match parts.next() {
            Some("insert") => {
                let n: u64 = parts
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("line {}: insert needs a count", lineno + 1))?
                    .parse()
                    .map_err(|e| anyhow::anyhow!("line {}: bad count: {e}", lineno + 1))?;
                running += n;
                steps.push(Step::Insert(n));
            }
            Some("work") => {
                let c: u32 = parts
                    .next()
                    .ok_or_else(|| anyhow::anyhow!("line {}: work needs a call count", lineno + 1))?
                    .parse()
                    .map_err(|e| anyhow::anyhow!("line {}: bad count: {e}", lineno + 1))?;
                steps.push(Step::Work(c));
            }
            Some("flatten") => steps.push(Step::Flatten),
            Some("seal") => steps.push(Step::Seal),
            Some(other) => anyhow::bail!("line {}: unknown step '{other}'", lineno + 1),
            None => {}
        }
        if parts.next().is_some() {
            anyhow::bail!("line {}: trailing tokens", lineno + 1);
        }
    }
    Ok(WorkloadSpec { name, steps, expected_final: running })
}

/// Save to a file.
pub fn save(w: &WorkloadSpec, path: &Path) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, to_text(w))?;
    Ok(())
}

/// Load from a file.
pub fn load(path: &Path) -> anyhow::Result<WorkloadSpec> {
    from_text(&std::fs::read_to_string(path)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let w = WorkloadSpec::two_phase(1_000_000, 3, 100, 5);
        let text = to_text(&w);
        let back = from_text(&text).unwrap();
        assert_eq!(back.steps, w.steps);
        assert_eq!(back.name, w.name);
        assert_eq!(back.expected_final, w.total_inserts());
    }

    #[test]
    fn seal_steps_roundtrip() {
        let w = WorkloadSpec::two_phase_sharded(10_000, 1, 2, 3);
        let text = to_text(&w);
        assert!(text.contains("seal\n"));
        let back = from_text(&text).unwrap();
        assert_eq!(back.steps, w.steps);
    }

    #[test]
    fn parse_errors_are_located() {
        assert!(from_text("insert").unwrap_err().to_string().contains("line 1"));
        assert!(from_text("insert 5\nbogus 3").unwrap_err().to_string().contains("line 2"));
        assert!(from_text("work 1 extra").unwrap_err().to_string().contains("trailing"));
        assert!(from_text("insert notanumber").is_err());
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let w = from_text("# name: t1\n\n# a comment\ninsert 10\nflatten\nwork 2\n").unwrap();
        assert_eq!(w.name, "t1");
        assert_eq!(w.steps, vec![Step::Insert(10), Step::Flatten, Step::Work(2)]);
    }

    #[test]
    fn file_roundtrip() {
        let dir = std::env::temp_dir().join("ggarray_trace_test");
        let path = dir.join("w.trace");
        let w = WorkloadSpec::duplication(100, 3);
        save(&w, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.steps, w.steps);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
