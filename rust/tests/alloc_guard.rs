//! Allocation-regression guard for the insert dispatch hot path.
//!
//! Installs `testkit::CountingAlloc` as the global allocator and asserts
//! that the steady-state dispatch loop — global sizes → route → shard
//! split → per-shard bulk placement → index rebuild — performs **zero**
//! heap allocations per batch once the scratch arena and the shard's
//! buckets are warm. This is the tentpole invariant of the zero-copy hot
//! path: every per-batch buffer lives in the `DispatchScratch` arena
//! (cleared, never dropped) and routed values flow as sub-slices of the
//! original batch. The 4-shard section extends the guarantee across the
//! work-stealing scheduler's chunk handoff: the serial charge pass, the
//! chunk injections into the worker deques, the concurrent fills on the
//! worker threads (steals included), and the drained+parked finish
//! barrier are all allocation-free too (the counter is global, so
//! worker-thread allocations would break the zero delta just the same).
//! A work-pass section pins the same contract on `Scheduler::run_work`,
//! which the old pool could not offer (its `run_work` snapshotted an
//! activity vector per call).
//!
//! This file must stay a dedicated test binary with this single test:
//! a sibling test running concurrently would allocate on another thread
//! and break the zero-delta assertion. (The scheduler's own workers are
//! part of the system under test, not bystanders.)

use ggarray::coordinator::scheduler::Scheduler;
use ggarray::coordinator::router::{DispatchScratch, Policy};
use ggarray::coordinator::service::{dispatch_insert, dispatch_insert_pooled};
use ggarray::coordinator::shard::{Shard, ShardConfig};
use ggarray::insertion::InsertionKind;
use ggarray::sim::spec::DeviceSpec;
use ggarray::testkit::CountingAlloc;
use ggarray::workload::synth_f32;

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc::new();

fn build_shards(shard_count: usize, blocks_per_shard: usize) -> Vec<Shard> {
    (0..shard_count)
        .map(|id| {
            Shard::new(ShardConfig {
                id,
                blocks: blocks_per_shard,
                first_bucket_size: 1 << 14,
                insertion: InsertionKind::WarpScan,
                device: DeviceSpec::a100(),
                heap_bytes: 1 << 30,
            })
        })
        .collect()
}

#[test]
fn steady_state_insert_dispatch_is_allocation_free() {
    // The 1-shard insert case of the acceptance criteria: 4 blocks with
    // 16Ki-element first buckets.
    let blocks = 4usize;
    let mut shards = build_shards(1, blocks);
    let mut scratch = DispatchScratch::new();
    let values: Vec<f32> = (0..1024u64).map(synth_f32).collect();

    // Warm-up: fills the scratch arena, allocates the early buckets and
    // the simulated clock's ledger entries. 80 batches × 1024 values =
    // 20480 elements per block; bucket 1 has been allocated by then, so
    // per-block capacity is 16384 + 32768 = 49152.
    for seq in 0..80u64 {
        let out = dispatch_insert(&mut shards, blocks, Policy::Even, seq, &values, &mut scratch);
        assert_eq!(out.applied, 1024);
        assert!(out.oom.is_none());
    }

    // Steady state: the next 16 batches (up to 24576 per block) fit
    // entirely within allocated bucket capacity — the dispatch loop must
    // not touch the heap at all.
    let before = CountingAlloc::allocations();
    for seq in 80..96u64 {
        let out = dispatch_insert(&mut shards, blocks, Policy::Even, seq, &values, &mut scratch);
        assert_eq!(out.applied, 1024);
    }
    let delta = CountingAlloc::allocations() - before;
    assert_eq!(
        delta, 0,
        "steady-state insert dispatch performed {delta} heap allocations over 16 batches"
    );

    // The data actually landed (this is a real insert loop, not a no-op).
    assert_eq!(shards[0].len(), 96 * 1024);
    assert_eq!(shards[0].get(0), Some(synth_f32(0)));

    // LeastLoaded routes through the in-place water-filling path (index
    // sort included) without allocating either. One warm-up call first:
    // the arena's order buffer is grown lazily by the first LeastLoaded
    // route.
    dispatch_insert(&mut shards, blocks, Policy::LeastLoaded, 96, &values, &mut scratch);
    let before = CountingAlloc::allocations();
    for seq in 97..104u64 {
        let out =
            dispatch_insert(&mut shards, blocks, Policy::LeastLoaded, seq, &values, &mut scratch);
        assert_eq!(out.applied, 1024);
    }
    let delta = CountingAlloc::allocations() - before;
    assert_eq!(delta, 0, "LeastLoaded dispatch allocated {delta} times");

    // ------------------------------------------------------------------
    // 4-shard dispatch with the work-stealing scheduler: the
    // zero-allocation invariant must hold across the chunk handoff —
    // the serial charge pass, chunk injection into the per-worker
    // deques (capacity retained across phases), condvar wake, the
    // concurrent fills on the worker threads (wherever steals land
    // them), and the drained+parked finish barrier. The global counter
    // sees every thread, so this proves the whole fan-out round trip
    // never touches the allocator in steady state.
    // ------------------------------------------------------------------
    let bps = 1usize; // 4 shards × 1 block: every shard gets a sub-batch
    let mut shards = build_shards(4, bps);
    let sched = Scheduler::new(4);
    // Warm-up: spawns nothing (workers already live), but fills buckets,
    // arena buffers, deque capacity and the clock ledgers.
    for seq in 0..80u64 {
        let out =
            dispatch_insert_pooled(&sched, &mut shards, bps, Policy::Even, seq, &values, &mut scratch)
                .unwrap();
        assert_eq!(out.applied, 1024);
        assert!(out.oom.is_none());
    }
    let before = CountingAlloc::allocations();
    for seq in 80..96u64 {
        let out =
            dispatch_insert_pooled(&sched, &mut shards, bps, Policy::Even, seq, &values, &mut scratch)
                .unwrap();
        assert_eq!(out.applied, 1024);
    }
    let delta = CountingAlloc::allocations() - before;
    assert_eq!(
        delta, 0,
        "steady-state scheduled 4-shard dispatch performed {delta} heap allocations over 16 \
         batches (the chunk handoff must stay allocation-free)"
    );
    // The data landed across all four shards — a real concurrent loop.
    assert_eq!(shards.iter().map(|s| s.len()).sum::<usize>(), 96 * 1024);
    for shard in &shards {
        assert_eq!(shard.len(), 24 * 1024);
    }
    assert_eq!(shards[0].get(0), Some(synth_f32(0)));

    // ------------------------------------------------------------------
    // Scheduled work passes are allocation-free too. The old pool's
    // `run_work` snapshotted a per-call `Vec<bool>` activity mask; the
    // scheduler decides per shard at injection time instead.
    // ------------------------------------------------------------------
    sched.run_work(&mut shards, None, 4).unwrap(); // warm the work chunk path
    let before = CountingAlloc::allocations();
    for _ in 0..16 {
        assert_eq!(sched.run_work(&mut shards, None, 4).unwrap(), 0);
    }
    let delta = CountingAlloc::allocations() - before;
    assert_eq!(
        delta, 0,
        "steady-state scheduled work pass performed {delta} heap allocations over 16 calls"
    );
}
