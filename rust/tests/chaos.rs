//! Chaos suite: deterministic fault injection across every registered
//! fault site (`ggarray::faults::SITES`).
//!
//! Build-gated: the whole file compiles to nothing without
//! `RUSTFLAGS='--cfg ggfault'` (ci.sh's chaos stage sets it — the
//! distinct flags fingerprint makes this a one-off rebuild, exactly
//! like the `ggcheck` model-check stage).
//!
//! The contract, per site × firing (see EXPERIMENTS.md §Robustness):
//!
//! * **Abort** sites — the in-flight op fails with a typed
//!   [`ExecError`] (or is silently absorbed by a fire-and-forget
//!   drain), the simulated ledger rolls back byte-identically, the
//!   conservation invariant `len == elements_inserted` holds, and every
//!   subsequent request succeeds. The one documented exception to byte
//!   identity is `Work` numerics on shards whose chunk completed before
//!   the panic (sequential f32 adds cannot be exactly reversed); the
//!   ledger still rewinds fully.
//! * **Degrade** sites — no error surfaces at all: the scheduler group
//!   runs with fewer workers (floor 1) and every observable result is
//!   byte-identical to the fault-free run, with the loss recorded in
//!   the `degraded_workers` / `spawn_failures` ledger.
//! * **Fatal** sites — the service worker's handler loop dies, and the
//!   supervisor (`coordinator::supervisor`) catches it: the loop
//!   respawns over the surviving store state and the un-acked request
//!   replays exactly once, so the observable trace is byte-identical
//!   to the fault-free oracle, sessions never observe `Closed`, and
//!   the failover is ledgered (`worker_restarts` / `replayed_requests`
//!   in the metrics snapshot). Never a hang, never a lost or doubled
//!   request.
//! * **Delay** sites (the `*.slow` twins) — a deterministic 25 ms stall
//!   instead of a panic: a straggling chunk is stolen around (the
//!   work-stealing gate) rather than waited on, nothing observable
//!   changes except latency — the trace stays byte-identical to the
//!   oracle — and the straggler surfaces in the tail-latency ledger
//!   (`p99_latency_us` / `max_latency_us` ≥ the injected stall).
//! * A plan that never fires (nth beyond the run's crossings, or a
//!   scheduler site under serial execution) must leave the run
//!   byte-identical to the fault-free oracle.
//!
//! **Composed plans** (`FaultPlan::then`) chain ordered steps so a
//! second fault can fire *inside* the recovery from the first — a panic
//! during the heal respawn, or an abort while a fully-degraded group
//! drains inline. Each composed scenario is checked against the same
//! tier contracts: typed errors only, ledger conserved, byte-identical
//! recovery.
//!
//! Fault plans are process-wide one-at-a-time slots, and an armed
//! plan's crossing counter would be perturbed by *any* concurrently
//! running coordinator — so every test body holds the file-local
//! `EXCLUSIVE` mutex, making the suite deterministic at any
//! `--test-threads` setting.
//!
//! Tests named `smoke_*` form the quick subset run by `ci.sh --quick`.
#![cfg(ggfault)]

use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

use ggarray::coordinator::request::{checksum, Admission, ExecError, Request, Response};
use ggarray::coordinator::router::{DispatchScratch, Policy};
use ggarray::coordinator::scheduler::{PhaseAbort, Scheduler};
use ggarray::coordinator::service::{
    dispatch_insert_pooled, Coordinator, CoordinatorConfig,
};
use ggarray::coordinator::shard::{Shard, ShardConfig};
use ggarray::coordinator::batcher::BatchConfig;
use ggarray::coordinator::metrics::MetricsSnapshot;
use ggarray::faults::{self, FaultPlan, SiteKind, DELAY_STALL, SITES};
use ggarray::insertion::InsertionKind;
use ggarray::sim::spec::DeviceSpec;
use ggarray::workload::synth_f32;

/// Serialises test bodies: the fault injector is a process-wide slot
/// and crossing counts must not see another test's coordinators.
static EXCLUSIVE: Mutex<()> = Mutex::new(());

fn exclusive() -> MutexGuard<'static, ()> {
    EXCLUSIVE.lock().unwrap_or_else(|e| e.into_inner())
}

/// The injected stall, in the unit the latency ledger reports.
fn stall_us() -> u64 {
    DELAY_STALL.as_micros() as u64
}

// ---------------------------------------------------------------------
// Scheduler-level byte-identity: a panic-aborted phase must leave the
// shards indistinguishable from the op never having been dispatched.
// ---------------------------------------------------------------------

fn build_shards(shard_count: usize, blocks_per_shard: usize) -> Vec<Shard> {
    (0..shard_count)
        .map(|id| {
            Shard::new(ShardConfig {
                id,
                blocks: blocks_per_shard,
                first_bucket_size: 1 << 10,
                insertion: InsertionKind::WarpScan,
                device: DeviceSpec::a100(),
                heap_bytes: 1 << 30,
            })
        })
        .collect()
}

/// Full per-shard fingerprint: length, allocation accounting, heap
/// residency, simulated-clock bit pattern and a content checksum.
fn fingerprint(shards: &[Shard]) -> Vec<(usize, u64, u64, u64, u64)> {
    shards
        .iter()
        .map(|s| {
            let data: Vec<f32> = (0..s.len() as u64).map(|i| s.get(i).unwrap()).collect();
            (s.len(), s.allocated_bytes(), s.heap_used(), s.sim_now_us().to_bits(), checksum(&data))
        })
        .collect()
}

/// Ledger-only fingerprint (no content): what `Work`'s abort contract
/// guarantees — completed chunks' f32 updates are the documented
/// byte-identity exception.
fn ledger_fingerprint(shards: &[Shard]) -> Vec<(usize, u64, u64, u64)> {
    shards
        .iter()
        .map(|s| (s.len(), s.allocated_bytes(), s.heap_used(), s.sim_now_us().to_bits()))
        .collect()
}

fn batch(seed: u64) -> Vec<f32> {
    (0..256u64).map(|i| synth_f32(seed * 256 + i)).collect()
}

#[test]
fn smoke_insert_abort_rolls_back_byte_identically() {
    let _x = exclusive();
    faults::quiet_panic_hook();
    let values: Vec<f32> = (0..1024u64).map(synth_f32).collect();
    let mut a = build_shards(4, 1);
    let mut b = build_shards(4, 1);
    let sched_a = Scheduler::new(2);
    let sched_b = Scheduler::new(2);
    let mut scr_a = DispatchScratch::new();
    let mut scr_b = DispatchScratch::new();
    for seq in 0..8u64 {
        dispatch_insert_pooled(&sched_a, &mut a, 1, Policy::Even, seq, &values, &mut scr_a)
            .unwrap();
        dispatch_insert_pooled(&sched_b, &mut b, 1, Policy::Even, seq, &values, &mut scr_b)
            .unwrap();
    }
    assert_eq!(fingerprint(&a), fingerprint(&b), "twins diverged before any fault");

    // Kill the first fill chunk of the next batch on the faulted twin.
    let pre = fingerprint(&b);
    let guard = FaultPlan::first("scheduler.worker.fill").arm();
    let err = dispatch_insert_pooled(&sched_b, &mut b, 1, Policy::Even, 8, &values, &mut scr_b)
        .unwrap_err();
    assert!(guard.fired(), "pooled dispatch must cross the fill site");
    drop(guard);
    assert!(
        matches!(err, ExecError::ChunkPanic { op: "insert", chunks } if chunks >= 1),
        "unexpected abort error: {err:?}"
    );
    assert_eq!(
        fingerprint(&b),
        pre,
        "panic-aborted insert must roll back byte-identically (len, heap, clock, content)"
    );
    // The dead worker was healed (respawned), not leaked.
    assert!(sched_b.counters().worker_respawns >= 1, "panicked worker was not respawned");

    // Replaying the same batch fault-free reconverges the twins exactly.
    dispatch_insert_pooled(&sched_a, &mut a, 1, Policy::Even, 8, &values, &mut scr_a).unwrap();
    dispatch_insert_pooled(&sched_b, &mut b, 1, Policy::Even, 8, &values, &mut scr_b).unwrap();
    assert_eq!(fingerprint(&a), fingerprint(&b), "retry after abort must be byte-identical");
    assert_eq!(a[0].get(0), Some(synth_f32(0)));
}

#[test]
fn work_abort_rewinds_the_precharged_ledger() {
    let _x = exclusive();
    faults::quiet_panic_hook();
    let values: Vec<f32> = (0..1024u64).map(synth_f32).collect();
    let mut shards = build_shards(4, 1);
    let sched = Scheduler::new(2);
    let mut scr = DispatchScratch::new();
    for seq in 0..4u64 {
        dispatch_insert_pooled(&sched, &mut shards, 1, Policy::Even, seq, &values, &mut scr)
            .unwrap();
    }
    let pre = ledger_fingerprint(&shards);
    let guard = FaultPlan::first("scheduler.worker.work").arm();
    let err = sched.run_work(&mut shards, None, 8).unwrap_err();
    assert!(guard.fired());
    drop(guard);
    assert!(matches!(err, ExecError::ChunkPanic { op: "work", .. }));
    // The serial pre-charge was rewound on every shard: the simulated
    // ledger reads as if the call never ran. (Content is exempt —
    // completed chunks' f32 updates are not reversible.)
    assert_eq!(ledger_fingerprint(&shards), pre, "work abort must rewind the rw_b pre-charges");
    // And the next call goes through.
    sched.run_work(&mut shards, None, 8).unwrap();
}

#[test]
fn gather_abort_leaves_the_store_untouched() {
    let _x = exclusive();
    faults::quiet_panic_hook();
    let values: Vec<f32> = (0..1024u64).map(synth_f32).collect();
    let mut shards = build_shards(4, 1);
    let sched = Scheduler::new(2);
    let mut scr = DispatchScratch::new();
    for seq in 0..4u64 {
        dispatch_insert_pooled(&sched, &mut shards, 1, Policy::Even, seq, &values, &mut scr)
            .unwrap();
    }
    let live: usize = shards.iter().map(|s| s.len()).sum();
    let mut dst = vec![0.0f32; live];
    scr.fill_gather_ranges(shards.iter().map(|s| s.len()));

    let pre = fingerprint(&shards);
    let guard = FaultPlan::first("scheduler.worker.copy").arm();
    let err = sched.run_flatten_temp(&mut shards, &mut dst, &scr.gather_ranges).unwrap_err();
    assert!(guard.fired());
    drop(guard);
    assert!(matches!(err, PhaseAbort::Panic(ExecError::ChunkPanic { op: "flatten", .. })));
    // Gather chunks only read shard state; the charge marks were
    // rewound, so the full fingerprint (content included) is intact.
    assert_eq!(fingerprint(&shards), pre, "gather abort must leave the store byte-identical");

    // The fault-free retry fills the snapshot completely.
    sched.run_flatten_temp(&mut shards, &mut dst, &scr.gather_ranges).unwrap();
    let mut expect = Vec::with_capacity(live);
    for s in &shards {
        expect.extend((0..s.len() as u64).map(|i| s.get(i).unwrap()));
    }
    assert_eq!(checksum(&dst), checksum(&expect), "retried gather produced wrong bytes");
}

// ---------------------------------------------------------------------
// Straggler skew: a stalled chunk must be stolen around, not waited on.
// ---------------------------------------------------------------------

/// The work-stealing gate under latency faults: stall the first fill
/// chunk a worker picks up for 25 ms. Round-robin injection gave that
/// worker more queued chunks, and its sibling drains its own deque in
/// microseconds — so the sibling MUST steal the straggler's backlog
/// (steal ledger grows), and because chunks are pure pre-charged data
/// movement, the stall changes not a single observable byte vs the
/// fault-free twin.
#[test]
fn smoke_straggler_is_stolen_around() {
    let _x = exclusive();
    faults::quiet_panic_hook();
    let values: Vec<f32> = (0..1024u64).map(synth_f32).collect();
    let mut a = build_shards(4, 1);
    let mut b = build_shards(4, 1);
    let sched_a = Scheduler::new(2);
    let sched_b = Scheduler::new(2);
    let mut scr_a = DispatchScratch::new();
    let mut scr_b = DispatchScratch::new();
    for seq in 0..2u64 {
        dispatch_insert_pooled(&sched_a, &mut a, 1, Policy::Even, seq, &values, &mut scr_a)
            .unwrap();
        dispatch_insert_pooled(&sched_b, &mut b, 1, Policy::Even, seq, &values, &mut scr_b)
            .unwrap();
    }
    let steals_before = sched_b.counters().steals;

    // 4 shards → 4 fill chunks round-robin over 2 deques (2 each): the
    // stalled worker still owes one queued chunk, which its idle
    // sibling must steal long before the 25 ms stall ends.
    let guard = FaultPlan::first("scheduler.worker.fill.slow").arm();
    dispatch_insert_pooled(&sched_b, &mut b, 1, Policy::Even, 2, &values, &mut scr_b).unwrap();
    assert!(guard.fired(), "scheduled dispatch must cross the fill.slow site");
    drop(guard);

    dispatch_insert_pooled(&sched_a, &mut a, 1, Policy::Even, 2, &values, &mut scr_a).unwrap();
    assert_eq!(
        fingerprint(&a),
        fingerprint(&b),
        "a straggler stall must not change a byte (len, heap, clock, content)"
    );
    assert!(
        sched_b.counters().steals > steals_before,
        "the straggler's queued chunk must be stolen around, not waited on \
         (steals {} -> {})",
        steals_before,
        sched_b.counters().steals
    );
}

// ---------------------------------------------------------------------
// Service-level chaos matrix: every registered site × first/second
// crossing × 1/4 shards × serial/scheduled execution, driven through
// the public request API against a fault-free oracle.
// ---------------------------------------------------------------------

fn cfg(shards: usize, executor_threads: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        device: DeviceSpec::a100(),
        blocks: 8,
        first_bucket_size: 1 << 10,
        insertion: InsertionKind::WarpScan,
        routing: Policy::Even,
        // One synchronous Insert == one flushed batch: faults inside the
        // dispatch surface on the very request that carried the values.
        batch: BatchConfig { max_values: 256, max_delay: Duration::from_secs(3600) },
        use_artifacts: false,
        work_iters: 8,
        heap_capacity: Some(16 << 20),
        epoch_heap: Some(8 << 20),
        shards,
        compact_segments: 4,
        executor_threads,
        frontend: Default::default(),
    }
}

/// One observable step outcome, reduced to its deterministic fields
/// (f64 costs compared as bit patterns; wall-clock fields dropped).
#[derive(Debug, Clone, PartialEq, Eq)]
enum Step {
    Inserted { count: u64, len: u64 },
    Worked { calls: u32, sim: u64, device: u64, pjrt: u64 },
    Flattened { len: u64, sim: u64, device: u64, checksum: u64 },
    Sealed { epoch: u64, epoch_len: u64, sealed_len: u64, segments: usize, sim: u64, checksum: u64 },
    Value(Option<u32>),
    Stats { len: u64, inserted: u64, seals: u64, flattens: u64, queries: u64, errors: u64, sim_insert: u64, sim_work: u64, sim_flatten: u64 },
    Failed(ExecError),
    Error(String),
    Other,
}

fn reduce(resp: Response) -> Step {
    match resp {
        Response::Inserted { count, len, .. } => Step::Inserted { count, len },
        Response::Worked { calls, sim_us, device_us, pjrt_executions } => Step::Worked {
            calls,
            sim: sim_us.to_bits(),
            device: device_us.to_bits(),
            pjrt: pjrt_executions,
        },
        Response::Flattened { len, sim_us, device_us, checksum } => {
            Step::Flattened { len, sim: sim_us.to_bits(), device: device_us.to_bits(), checksum }
        }
        Response::Sealed { epoch, epoch_len, sealed_len, sealed_segments, sim_us, checksum, .. } => {
            Step::Sealed {
                epoch,
                epoch_len,
                sealed_len,
                segments: sealed_segments,
                sim: sim_us.to_bits(),
                checksum,
            }
        }
        Response::Value(v) => Step::Value(v.map(f32::to_bits)),
        Response::Stats(s) => Step::Stats {
            len: s.len,
            inserted: s.elements_inserted,
            seals: s.seals,
            flattens: s.flattens,
            queries: s.queries,
            errors: s.errors,
            sim_insert: s.sim_insert_ms.to_bits(),
            sim_work: s.sim_work_ms.to_bits(),
            sim_flatten: s.sim_flatten_ms.to_bits(),
        },
        Response::Failed(e) => Step::Failed(e),
        Response::Error(msg) => Step::Error(msg),
        _ => Step::Other,
    }
}

/// The fixed request script every matrix cell runs: inserts, work, two
/// seals (copy chunks cross twice), a flatten snapshot, point queries
/// and a stats read — 13 calls, all synchronous.
fn run_script(c: &Coordinator) -> Vec<Step> {
    let mut trace = Vec::new();
    for seed in 0..4u64 {
        trace.push(reduce(c.call(Request::Insert { values: batch(seed) })));
    }
    trace.push(reduce(c.call(Request::Work { calls: 2 })));
    trace.push(reduce(c.call(Request::Seal)));
    for seed in 4..6u64 {
        trace.push(reduce(c.call(Request::Insert { values: batch(seed) })));
    }
    trace.push(reduce(c.call(Request::Flatten)));
    trace.push(reduce(c.call(Request::Seal)));
    trace.push(reduce(c.call(Request::Query { index: 0 })));
    trace.push(reduce(c.call(Request::Query { index: 700 })));
    trace.push(reduce(c.call(Request::Stats)));
    trace
}

/// Post-fault probes: the store must keep serving after any contained
/// fault. Returns the final snapshot for ledger assertions.
fn probe_recovery(c: &Coordinator, site: &'static str, nth: u64) -> MetricsSnapshot {
    let r = c.call(Request::Insert { values: batch(99) });
    assert!(
        matches!(r, Response::Inserted { count: 256, .. }),
        "[{site} nth={nth}] post-fault insert failed: {r:?}"
    );
    let r = c.call(Request::Seal);
    assert!(matches!(r, Response::Sealed { .. }), "[{site} nth={nth}] post-fault seal failed: {r:?}");
    let r = c.call(Request::Query { index: 0 });
    assert!(
        matches!(r, Response::Value(Some(_))),
        "[{site} nth={nth}] post-fault query failed: {r:?}"
    );
    match c.call(Request::Stats) {
        Response::Stats(s) => s,
        other => panic!("[{site} nth={nth}] post-fault stats failed: {other:?}"),
    }
}

#[test]
fn chaos_matrix_every_site_upholds_its_contract() {
    let _x = exclusive();
    faults::quiet_panic_hook();
    for &(shards, execs) in &[(1usize, 1usize), (1, 4), (4, 1), (4, 4)] {
        let config = cfg(shards, execs);
        // Fault-free oracle for this geometry (no plan armed).
        let oracle = {
            let c = Coordinator::start(config.clone());
            let t = run_script(&c);
            c.shutdown();
            t
        };
        assert!(
            !oracle.iter().any(|s| matches!(s, Step::Failed(_) | Step::Error(_))),
            "oracle run must be clean ({shards} shards, {execs} executors): {oracle:?}"
        );

        for site in SITES {
            for nth in [1u64, 2] {
                // Arm before construction: Degrade sites cross during the
                // scheduler's startup spawns.
                let guard = FaultPlan { site: site.name, nth }.arm();
                let c = Coordinator::start(config.clone());
                let trace = run_script(&c);
                let fired = guard.fired();
                drop(guard); // disarm before the recovery probes

                let tag = format!(
                    "site={} nth={nth} shards={shards} execs={execs} fired={fired}",
                    site.name
                );
                match (fired, site.kind) {
                    (false, _) => {
                        // Arm (b): an unfired plan must not perturb a bit.
                        assert_eq!(trace, oracle, "[{tag}] unfired plan changed the trace");
                    }
                    (true, SiteKind::Degrade) => {
                        // No error surfaces; results byte-identical; the
                        // lost worker is ledgered.
                        assert_eq!(trace, oracle, "[{tag}] degraded run diverged from oracle");
                        let s = probe_recovery(&c, site.name, nth);
                        assert!(
                            s.degraded_workers >= 1 && s.spawn_failures >= 1,
                            "[{tag}] degrade not ledgered: {} degraded, {} spawn failures",
                            s.degraded_workers,
                            s.spawn_failures
                        );
                    }
                    (true, SiteKind::Abort) => {
                        // At most one request observes the typed error
                        // (a fault inside a barrier drain is absorbed and
                        // only ledgered); everything else must succeed.
                        let failed = trace
                            .iter()
                            .filter(|s| matches!(s, Step::Failed(_)))
                            .count();
                        assert!(failed <= 1, "[{tag}] more than one failed step: {trace:?}");
                        assert!(
                            !trace.iter().any(|s| matches!(s, Step::Error(_))),
                            "[{tag}] untyped error leaked: {trace:?}"
                        );
                        let s = probe_recovery(&c, site.name, nth);
                        assert!(s.errors >= 1, "[{tag}] abort not ledgered in errors");
                        // Conservation: every resident element was counted
                        // applied, every aborted batch fully rolled back.
                        assert_eq!(
                            s.len, s.elements_inserted,
                            "[{tag}] ledger conservation broken: len {} vs inserted {}",
                            s.len, s.elements_inserted
                        );
                    }
                    (true, SiteKind::Fatal) => {
                        // The handler loop died mid-script — and the
                        // supervisor made it invisible: respawned loop,
                        // un-acked request replayed exactly once, trace
                        // byte-identical to the oracle, failover
                        // ledgered, sessions open.
                        assert_eq!(
                            trace, oracle,
                            "[{tag}] supervised restart diverged from the oracle"
                        );
                        let mut sess = c.session();
                        let adm = sess.try_insert(vec![1.0; 8]);
                        assert!(
                            adm.is_accepted(),
                            "[{tag}] session on a supervised service must stay open: {adm:?}"
                        );
                        let s = probe_recovery(&c, site.name, nth);
                        assert!(
                            s.worker_restarts >= 1,
                            "[{tag}] restart not ledgered: {} worker restarts",
                            s.worker_restarts
                        );
                        assert!(
                            s.replayed_requests >= 1,
                            "[{tag}] replay not ledgered: {} replayed requests",
                            s.replayed_requests
                        );
                        assert_eq!(
                            s.len, s.elements_inserted,
                            "[{tag}] replay broke conservation: len {} vs inserted {}",
                            s.len, s.elements_inserted
                        );
                    }
                    (true, SiteKind::Delay) => {
                        // A stall is not a fault: byte-identical trace,
                        // no error, and the straggler surfaces only in
                        // the tail-latency ledger.
                        assert_eq!(trace, oracle, "[{tag}] stalled run diverged from oracle");
                        let s = probe_recovery(&c, site.name, nth);
                        assert!(
                            s.max_latency_us >= stall_us(),
                            "[{tag}] stall missing from the latency ledger: max {} µs < {} µs",
                            s.max_latency_us,
                            stall_us()
                        );
                        // Few enough requests that p99 is the max bucket:
                        // the tail percentile must expose the straggler.
                        assert!(
                            s.p99_latency_us >= stall_us(),
                            "[{tag}] p99 {} µs under-reports the {} µs stall",
                            s.p99_latency_us,
                            stall_us()
                        );
                    }
                }
                c.shutdown();
            }
        }
    }
}

// ---------------------------------------------------------------------
// Supervisor tier: transparent failover of the service worker, replay
// idempotence across every op arm, and graceful shutdown replay.
// ---------------------------------------------------------------------

/// Acceptance smoke for the supervisor: kill the handler loop under a
/// live request — the caller still gets its success response (replayed
/// exactly once over the surviving store), sessions stay open, and the
/// failover is ledgered without counting as an error.
#[test]
fn smoke_supervisor_restarts_and_replays_exactly_once() {
    let _x = exclusive();
    faults::quiet_panic_hook();
    let c = Coordinator::start(cfg(4, 4));
    for seed in 0..2u64 {
        let r = c.call(Request::Insert { values: batch(seed) });
        assert!(matches!(r, Response::Inserted { count: 256, .. }), "warm insert failed: {r:?}");
    }

    let guard = FaultPlan::first("service.worker.fatal").arm();
    let r = c.call(Request::Insert { values: batch(2) });
    assert!(guard.fired(), "the next call must cross the fatal site");
    drop(guard);
    assert!(
        matches!(r, Response::Inserted { count: 256, len: 768 }),
        "the killed request must be replayed to success, got {r:?}"
    );

    // Sessions never observe the failover.
    let mut sess = c.session();
    assert!(sess.try_insert(vec![7.0; 8]).is_accepted(), "session must stay open");

    let s = probe_recovery(&c, "service.worker.fatal", 1);
    assert_eq!(s.worker_restarts, 1, "exactly one supervised restart");
    assert_eq!(s.replayed_requests, 1, "exactly one replayed request");
    assert_eq!(s.errors, 0, "a successful replay is not an error");
    assert_eq!(s.len, s.elements_inserted, "replay must not lose or double values");
    c.shutdown();
}

/// Replay idempotence, one op arm at a time: a script touching every
/// request kind, killed at each successive call, must produce a trace
/// byte-identical to the fault-free oracle with exactly one restart and
/// one replay — no op arm loses, doubles, or corrupts its request when
/// it is the one replayed.
#[test]
fn supervisor_replay_is_idempotent_for_every_op_arm() {
    let _x = exclusive();
    faults::quiet_panic_hook();
    let config = cfg(4, 4);
    let script = |c: &Coordinator| -> Vec<Step> {
        vec![
            reduce(c.call(Request::Insert { values: batch(0) })),
            reduce(c.call(Request::Work { calls: 2 })),
            reduce(c.call(Request::Seal)),
            reduce(c.call(Request::Insert { values: batch(1) })),
            reduce(c.call(Request::Flatten)),
            reduce(c.call(Request::Query { index: 10 })),
            reduce(c.call(Request::Stats)),
            reduce(c.call(Request::Clear)),
            reduce(c.call(Request::Insert { values: batch(2) })),
            reduce(c.call(Request::Stats)),
        ]
    };
    let oracle = {
        let c = Coordinator::start(config.clone());
        let t = script(&c);
        c.shutdown();
        t
    };
    assert!(
        !oracle.iter().any(|s| matches!(s, Step::Failed(_) | Step::Error(_))),
        "oracle run must be clean: {oracle:?}"
    );

    let calls = oracle.len() as u64;
    for nth in 1..=calls {
        let guard = FaultPlan { site: "service.worker.fatal", nth }.arm();
        let c = Coordinator::start(config.clone());
        let trace = script(&c);
        assert!(guard.fired(), "[nth={nth}] the script's {calls} calls must cross the site");
        drop(guard);
        assert_eq!(trace, oracle, "[nth={nth}] replayed op arm diverged from the oracle");
        let s = c.call(Request::Stats).expect_stats();
        assert_eq!(s.worker_restarts, 1, "[nth={nth}] exactly one restart");
        assert_eq!(s.replayed_requests, 1, "[nth={nth}] exactly one replay");
        assert_eq!(s.errors, 0, "[nth={nth}] a successful replay is not an error");
        c.shutdown();
    }
}

/// A fatal fault on the Shutdown request itself: the supervisor replays
/// it, the caller gets its ack, and the worker thread still stops
/// cleanly — failover must not turn a graceful stop into a zombie loop.
#[test]
fn supervisor_replays_shutdown_and_still_stops() {
    let _x = exclusive();
    faults::quiet_panic_hook();
    let c = Coordinator::start(cfg(1, 1));
    let r = c.call(Request::Insert { values: batch(0) });
    assert!(matches!(r, Response::Inserted { count: 256, .. }));

    // nth=1 from here: the very next call — Shutdown — crosses first.
    let guard = FaultPlan::first("service.worker.fatal").arm();
    let r = c.call(Request::Shutdown);
    assert!(guard.fired(), "shutdown must cross the fatal site");
    drop(guard);
    assert!(
        matches!(r, Response::ShuttingDown),
        "replayed shutdown must still be acked, got {r:?}"
    );
    // Drop joins the worker thread: a hang here means the replayed
    // Shutdown failed to stop the supervisor loop.
    drop(c);
}

// ---------------------------------------------------------------------
// Composed faults: a second fault firing inside the recovery from the
// first. Same contracts — typed errors only, ledger conserved,
// byte-identical rollback, service keeps serving.
// ---------------------------------------------------------------------

/// Chunk panic, then a fault during the heal: the fill abort kills a
/// scheduler worker, and the respawn that `finish` attempts for it is
/// itself refused. The op still aborts typed-and-rolled-back, and the
/// group degrades (permanently smaller) instead of leaking or hanging.
#[test]
fn smoke_composed_abort_then_failed_heal_degrades() {
    let _x = exclusive();
    faults::quiet_panic_hook();
    let c = Coordinator::start(cfg(4, 4));
    for seed in 0..2u64 {
        let r = c.call(Request::Insert { values: batch(seed) });
        assert!(matches!(r, Response::Inserted { count: 256, .. }), "warm insert failed: {r:?}");
    }

    let guard = FaultPlan::first("scheduler.worker.fill")
        .then(FaultPlan::first("scheduler.spawn"))
        .arm();
    let r = c.call(Request::Insert { values: batch(2) });
    assert_eq!(guard.fired_steps(), 2, "both steps must fire: the abort, then the heal spawn");
    assert!(guard.fired());
    drop(guard);
    assert!(
        matches!(r, Response::Failed(ExecError::ChunkPanic { op: "insert", .. })),
        "composed fault must still surface the typed abort, got {r:?}"
    );

    let s = probe_recovery(&c, "scheduler.worker.fill+scheduler.spawn", 1);
    assert!(s.degraded_workers >= 1, "failed heal must be ledgered as degradation");
    assert!(s.spawn_failures >= 1, "refused respawn must be ledgered");
    assert_eq!(s.errors, 1, "exactly the aborted insert is an error");
    assert_eq!(s.len, s.elements_inserted, "conservation across composed faults");
    assert_eq!(s.len, 3 * 256, "two warm batches + the recovery probe batch");
    c.shutdown();
}

/// Every construction spawn refused, then an abort while the fully
/// degraded group drains inline: with zero live workers the phase falls
/// back to the coordinator thread, where the fill panic must still be
/// contained, rolled back, and typed — the floor-1 path honours the
/// same abort contract as the scheduled path.
#[test]
fn smoke_composed_degraded_inline_drain_still_aborts_typed() {
    let _x = exclusive();
    faults::quiet_panic_hook();
    // Armed BEFORE start: steps 1 and 2 refuse both construction
    // spawns, leaving the group fully degraded from birth.
    let guard = FaultPlan::first("scheduler.spawn")
        .then(FaultPlan::first("scheduler.spawn"))
        .then(FaultPlan::first("scheduler.worker.fill"))
        .arm();
    let c = Coordinator::start(cfg(4, 2));
    let r = c.call(Request::Insert { values: batch(0) });
    assert_eq!(guard.fired_steps(), 3, "two refused spawns, then the inline-drain abort");
    drop(guard);
    assert!(
        matches!(r, Response::Failed(ExecError::ChunkPanic { op: "insert", .. })),
        "inline-drain abort must be typed, got {r:?}"
    );

    let s = probe_recovery(&c, "scheduler.spawn×2+scheduler.worker.fill", 1);
    assert_eq!(s.degraded_workers, 2, "both construction spawns degraded");
    assert_eq!(s.spawn_failures, 2);
    assert_eq!(s.errors, 1, "exactly the aborted insert is an error");
    assert_eq!(s.len, s.elements_inserted, "inline abort must roll back exactly");
    assert_eq!(s.len, 256, "only the recovery probe batch landed");
    c.shutdown();
}

/// Composed fatal faults: kill the handler loop, then kill the *next*
/// serve pass too (the replay itself never crosses the fatal site, so
/// step 2 fires on the first fresh call after the failover). Both
/// failovers are transparent and both are ledgered.
#[test]
fn composed_double_fatal_survives_two_failovers() {
    let _x = exclusive();
    faults::quiet_panic_hook();
    let c = Coordinator::start(cfg(4, 4));
    let r = c.call(Request::Insert { values: batch(0) });
    assert!(matches!(r, Response::Inserted { count: 256, .. }));

    let guard = FaultPlan::first("service.worker.fatal")
        .then(FaultPlan::first("service.worker.fatal"))
        .arm();
    let r = c.call(Request::Insert { values: batch(1) });
    assert!(
        matches!(r, Response::Inserted { count: 256, len: 512 }),
        "first killed request must replay to success, got {r:?}"
    );
    let r = c.call(Request::Work { calls: 2 });
    assert!(
        matches!(r, Response::Worked { calls: 2, .. }),
        "second killed request must replay to success, got {r:?}"
    );
    assert_eq!(guard.fired_steps(), 2, "both fatal steps must fire");
    drop(guard);

    let s = probe_recovery(&c, "service.worker.fatal×2", 1);
    assert_eq!(s.worker_restarts, 2, "two supervised restarts");
    assert_eq!(s.replayed_requests, 2, "two replays, one per failover");
    assert_eq!(s.errors, 0);
    assert_eq!(s.len, s.elements_inserted);
    c.shutdown();
}

// ---------------------------------------------------------------------
// Acceptance criterion, end to end: a mid-chunk worker panic aborts the
// in-flight op with a typed error and the store keeps serving.
// ---------------------------------------------------------------------

#[test]
fn smoke_mid_chunk_panic_store_keeps_serving() {
    let _x = exclusive();
    faults::quiet_panic_hook();
    let c = Coordinator::start(cfg(4, 4));
    for seed in 0..4u64 {
        let r = c.call(Request::Insert { values: batch(seed) });
        assert!(matches!(r, Response::Inserted { count: 256, .. }), "warm insert failed: {r:?}");
    }

    let guard = FaultPlan::first("scheduler.worker.fill").arm();
    let r = c.call(Request::Insert { values: batch(4) });
    assert!(guard.fired(), "scheduled insert dispatch must cross the fill site");
    drop(guard);
    assert!(
        matches!(r, Response::Failed(ExecError::ChunkPanic { op: "insert", .. })),
        "faulted insert response: {r:?}"
    );

    // Subsequent Insert / Seal / Query all succeed, and the ledger shows
    // exactly one aborted batch: 5 batches accepted, 4 + 1 post-fault
    // applied, len == elements_inserted.
    let s = probe_recovery(&c, "scheduler.worker.fill", 1);
    assert_eq!(s.len, 5 * 256, "one batch aborted, five landed");
    assert_eq!(s.len, s.elements_inserted);
    assert_eq!(s.errors, 1);
    assert!(s.worker_respawns >= 1, "panicked scheduler worker was not respawned");
    let r = c.call(Request::Query { index: s.len - 1 });
    assert!(matches!(r, Response::Value(Some(_))));
    c.shutdown();
}

/// Delay tier smoke: a stalled service handler must show up in the
/// tail-latency ledger while leaving every byte and every ledger
/// (errors included) untouched.
#[test]
fn smoke_stalled_handler_reports_in_the_tail() {
    let _x = exclusive();
    faults::quiet_panic_hook();
    let c = Coordinator::start(cfg(1, 1));
    let r = c.call(Request::Insert { values: batch(0) });
    assert!(matches!(r, Response::Inserted { count: 256, .. }));

    let guard = FaultPlan::first("service.worker.handle.slow").arm();
    let r = c.call(Request::Insert { values: batch(1) });
    assert!(guard.fired(), "the next handled request must cross the stall site");
    drop(guard);
    assert!(
        matches!(r, Response::Inserted { count: 256, len: 512 }),
        "a stall must not fail the request, got {r:?}"
    );

    let s = probe_recovery(&c, "service.worker.handle.slow", 1);
    assert!(
        s.max_latency_us >= stall_us() && s.p99_latency_us >= stall_us(),
        "stall missing from the tail ledger: p99 {} µs, max {} µs (stall {} µs)",
        s.p99_latency_us,
        s.max_latency_us,
        stall_us()
    );
    assert_eq!(s.errors, 0, "a stall is not an error");
    assert_eq!(s.worker_restarts, 0, "a stall is not a failover");
    assert_eq!(s.len, s.elements_inserted);
    c.shutdown();
}
