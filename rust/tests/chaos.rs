//! Chaos suite: deterministic fault injection across every registered
//! fault site (`ggarray::faults::SITES`).
//!
//! Build-gated: the whole file compiles to nothing without
//! `RUSTFLAGS='--cfg ggfault'` (ci.sh's chaos stage sets it — the
//! distinct flags fingerprint makes this a one-off rebuild, exactly
//! like the `ggcheck` model-check stage).
//!
//! The contract, per site × firing (see EXPERIMENTS.md §Robustness):
//!
//! * **Abort** sites — the in-flight op fails with a typed
//!   [`ExecError`] (or is silently absorbed by a fire-and-forget
//!   drain), the simulated ledger rolls back byte-identically, the
//!   conservation invariant `len == elements_inserted` holds, and every
//!   subsequent request succeeds. The one documented exception to byte
//!   identity is `Work` numerics on shards whose chunk completed before
//!   the panic (sequential f32 adds cannot be exactly reversed); the
//!   ledger still rewinds fully.
//! * **Degrade** sites — no error surfaces at all: the scheduler group
//!   runs with fewer workers (floor 1) and every observable result is
//!   byte-identical to the fault-free run, with the loss recorded in
//!   the `degraded_workers` / `spawn_failures` ledger.
//! * **Fatal** sites — the service worker dies; every subsequent call
//!   observes the typed `Failed(ServiceDown)` and sessions observe
//!   `Admission::Closed` with the payload handed back. Never a hang.
//! * A plan that never fires (nth beyond the run's crossings, or a
//!   scheduler site under serial execution) must leave the run
//!   byte-identical to the fault-free oracle.
//!
//! Fault plans are process-wide one-at-a-time slots, and an armed
//! plan's crossing counter would be perturbed by *any* concurrently
//! running coordinator — so every test body holds the file-local
//! `EXCLUSIVE` mutex, making the suite deterministic at any
//! `--test-threads` setting.
//!
//! Tests named `smoke_*` form the quick subset run by `ci.sh --quick`.
#![cfg(ggfault)]

use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

use ggarray::coordinator::request::{checksum, Admission, ExecError, Request, Response};
use ggarray::coordinator::router::{DispatchScratch, Policy};
use ggarray::coordinator::scheduler::{PhaseAbort, Scheduler};
use ggarray::coordinator::service::{
    dispatch_insert_pooled, Coordinator, CoordinatorConfig,
};
use ggarray::coordinator::shard::{Shard, ShardConfig};
use ggarray::coordinator::batcher::BatchConfig;
use ggarray::coordinator::metrics::MetricsSnapshot;
use ggarray::faults::{self, FaultPlan, SiteKind, SITES};
use ggarray::insertion::InsertionKind;
use ggarray::sim::spec::DeviceSpec;
use ggarray::workload::synth_f32;

/// Serialises test bodies: the fault injector is a process-wide slot
/// and crossing counts must not see another test's coordinators.
static EXCLUSIVE: Mutex<()> = Mutex::new(());

fn exclusive() -> MutexGuard<'static, ()> {
    EXCLUSIVE.lock().unwrap_or_else(|e| e.into_inner())
}

// ---------------------------------------------------------------------
// Scheduler-level byte-identity: a panic-aborted phase must leave the
// shards indistinguishable from the op never having been dispatched.
// ---------------------------------------------------------------------

fn build_shards(shard_count: usize, blocks_per_shard: usize) -> Vec<Shard> {
    (0..shard_count)
        .map(|id| {
            Shard::new(ShardConfig {
                id,
                blocks: blocks_per_shard,
                first_bucket_size: 1 << 10,
                insertion: InsertionKind::WarpScan,
                device: DeviceSpec::a100(),
                heap_bytes: 1 << 30,
            })
        })
        .collect()
}

/// Full per-shard fingerprint: length, allocation accounting, heap
/// residency, simulated-clock bit pattern and a content checksum.
fn fingerprint(shards: &[Shard]) -> Vec<(usize, u64, u64, u64, u64)> {
    shards
        .iter()
        .map(|s| {
            let data: Vec<f32> = (0..s.len() as u64).map(|i| s.get(i).unwrap()).collect();
            (s.len(), s.allocated_bytes(), s.heap_used(), s.sim_now_us().to_bits(), checksum(&data))
        })
        .collect()
}

/// Ledger-only fingerprint (no content): what `Work`'s abort contract
/// guarantees — completed chunks' f32 updates are the documented
/// byte-identity exception.
fn ledger_fingerprint(shards: &[Shard]) -> Vec<(usize, u64, u64, u64)> {
    shards
        .iter()
        .map(|s| (s.len(), s.allocated_bytes(), s.heap_used(), s.sim_now_us().to_bits()))
        .collect()
}

fn batch(seed: u64) -> Vec<f32> {
    (0..256u64).map(|i| synth_f32(seed * 256 + i)).collect()
}

#[test]
fn smoke_insert_abort_rolls_back_byte_identically() {
    let _x = exclusive();
    faults::quiet_panic_hook();
    let values: Vec<f32> = (0..1024u64).map(synth_f32).collect();
    let mut a = build_shards(4, 1);
    let mut b = build_shards(4, 1);
    let sched_a = Scheduler::new(2);
    let sched_b = Scheduler::new(2);
    let mut scr_a = DispatchScratch::new();
    let mut scr_b = DispatchScratch::new();
    for seq in 0..8u64 {
        dispatch_insert_pooled(&sched_a, &mut a, 1, Policy::Even, seq, &values, &mut scr_a)
            .unwrap();
        dispatch_insert_pooled(&sched_b, &mut b, 1, Policy::Even, seq, &values, &mut scr_b)
            .unwrap();
    }
    assert_eq!(fingerprint(&a), fingerprint(&b), "twins diverged before any fault");

    // Kill the first fill chunk of the next batch on the faulted twin.
    let pre = fingerprint(&b);
    let guard = FaultPlan::first("scheduler.worker.fill").arm();
    let err = dispatch_insert_pooled(&sched_b, &mut b, 1, Policy::Even, 8, &values, &mut scr_b)
        .unwrap_err();
    assert!(guard.fired(), "pooled dispatch must cross the fill site");
    drop(guard);
    assert!(
        matches!(err, ExecError::ChunkPanic { op: "insert", chunks } if chunks >= 1),
        "unexpected abort error: {err:?}"
    );
    assert_eq!(
        fingerprint(&b),
        pre,
        "panic-aborted insert must roll back byte-identically (len, heap, clock, content)"
    );
    // The dead worker was healed (respawned), not leaked.
    assert!(sched_b.counters().worker_respawns >= 1, "panicked worker was not respawned");

    // Replaying the same batch fault-free reconverges the twins exactly.
    dispatch_insert_pooled(&sched_a, &mut a, 1, Policy::Even, 8, &values, &mut scr_a).unwrap();
    dispatch_insert_pooled(&sched_b, &mut b, 1, Policy::Even, 8, &values, &mut scr_b).unwrap();
    assert_eq!(fingerprint(&a), fingerprint(&b), "retry after abort must be byte-identical");
    assert_eq!(a[0].get(0), Some(synth_f32(0)));
}

#[test]
fn work_abort_rewinds_the_precharged_ledger() {
    let _x = exclusive();
    faults::quiet_panic_hook();
    let values: Vec<f32> = (0..1024u64).map(synth_f32).collect();
    let mut shards = build_shards(4, 1);
    let sched = Scheduler::new(2);
    let mut scr = DispatchScratch::new();
    for seq in 0..4u64 {
        dispatch_insert_pooled(&sched, &mut shards, 1, Policy::Even, seq, &values, &mut scr)
            .unwrap();
    }
    let pre = ledger_fingerprint(&shards);
    let guard = FaultPlan::first("scheduler.worker.work").arm();
    let err = sched.run_work(&mut shards, None, 8).unwrap_err();
    assert!(guard.fired());
    drop(guard);
    assert!(matches!(err, ExecError::ChunkPanic { op: "work", .. }));
    // The serial pre-charge was rewound on every shard: the simulated
    // ledger reads as if the call never ran. (Content is exempt —
    // completed chunks' f32 updates are not reversible.)
    assert_eq!(ledger_fingerprint(&shards), pre, "work abort must rewind the rw_b pre-charges");
    // And the next call goes through.
    sched.run_work(&mut shards, None, 8).unwrap();
}

#[test]
fn gather_abort_leaves_the_store_untouched() {
    let _x = exclusive();
    faults::quiet_panic_hook();
    let values: Vec<f32> = (0..1024u64).map(synth_f32).collect();
    let mut shards = build_shards(4, 1);
    let sched = Scheduler::new(2);
    let mut scr = DispatchScratch::new();
    for seq in 0..4u64 {
        dispatch_insert_pooled(&sched, &mut shards, 1, Policy::Even, seq, &values, &mut scr)
            .unwrap();
    }
    let live: usize = shards.iter().map(|s| s.len()).sum();
    let mut dst = vec![0.0f32; live];
    scr.fill_gather_ranges(shards.iter().map(|s| s.len()));

    let pre = fingerprint(&shards);
    let guard = FaultPlan::first("scheduler.worker.copy").arm();
    let err = sched.run_flatten_temp(&mut shards, &mut dst, &scr.gather_ranges).unwrap_err();
    assert!(guard.fired());
    drop(guard);
    assert!(matches!(err, PhaseAbort::Panic(ExecError::ChunkPanic { op: "flatten", .. })));
    // Gather chunks only read shard state; the charge marks were
    // rewound, so the full fingerprint (content included) is intact.
    assert_eq!(fingerprint(&shards), pre, "gather abort must leave the store byte-identical");

    // The fault-free retry fills the snapshot completely.
    sched.run_flatten_temp(&mut shards, &mut dst, &scr.gather_ranges).unwrap();
    let mut expect = Vec::with_capacity(live);
    for s in &shards {
        expect.extend((0..s.len() as u64).map(|i| s.get(i).unwrap()));
    }
    assert_eq!(checksum(&dst), checksum(&expect), "retried gather produced wrong bytes");
}

// ---------------------------------------------------------------------
// Service-level chaos matrix: every registered site × first/second
// crossing × 1/4 shards × serial/scheduled execution, driven through
// the public request API against a fault-free oracle.
// ---------------------------------------------------------------------

fn cfg(shards: usize, executor_threads: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        device: DeviceSpec::a100(),
        blocks: 8,
        first_bucket_size: 1 << 10,
        insertion: InsertionKind::WarpScan,
        routing: Policy::Even,
        // One synchronous Insert == one flushed batch: faults inside the
        // dispatch surface on the very request that carried the values.
        batch: BatchConfig { max_values: 256, max_delay: Duration::from_secs(3600) },
        use_artifacts: false,
        work_iters: 8,
        heap_capacity: Some(16 << 20),
        epoch_heap: Some(8 << 20),
        shards,
        compact_segments: 4,
        executor_threads,
        frontend: Default::default(),
    }
}

/// One observable step outcome, reduced to its deterministic fields
/// (f64 costs compared as bit patterns; wall-clock fields dropped).
#[derive(Debug, Clone, PartialEq, Eq)]
enum Step {
    Inserted { count: u64, len: u64 },
    Worked { calls: u32, sim: u64, device: u64, pjrt: u64 },
    Flattened { len: u64, sim: u64, device: u64, checksum: u64 },
    Sealed { epoch: u64, epoch_len: u64, sealed_len: u64, segments: usize, sim: u64, checksum: u64 },
    Value(Option<u32>),
    Stats { len: u64, inserted: u64, seals: u64, flattens: u64, queries: u64, errors: u64, sim_insert: u64, sim_work: u64, sim_flatten: u64 },
    Failed(ExecError),
    Error(String),
    Other,
}

fn reduce(resp: Response) -> Step {
    match resp {
        Response::Inserted { count, len, .. } => Step::Inserted { count, len },
        Response::Worked { calls, sim_us, device_us, pjrt_executions } => Step::Worked {
            calls,
            sim: sim_us.to_bits(),
            device: device_us.to_bits(),
            pjrt: pjrt_executions,
        },
        Response::Flattened { len, sim_us, device_us, checksum } => {
            Step::Flattened { len, sim: sim_us.to_bits(), device: device_us.to_bits(), checksum }
        }
        Response::Sealed { epoch, epoch_len, sealed_len, sealed_segments, sim_us, checksum, .. } => {
            Step::Sealed {
                epoch,
                epoch_len,
                sealed_len,
                segments: sealed_segments,
                sim: sim_us.to_bits(),
                checksum,
            }
        }
        Response::Value(v) => Step::Value(v.map(f32::to_bits)),
        Response::Stats(s) => Step::Stats {
            len: s.len,
            inserted: s.elements_inserted,
            seals: s.seals,
            flattens: s.flattens,
            queries: s.queries,
            errors: s.errors,
            sim_insert: s.sim_insert_ms.to_bits(),
            sim_work: s.sim_work_ms.to_bits(),
            sim_flatten: s.sim_flatten_ms.to_bits(),
        },
        Response::Failed(e) => Step::Failed(e),
        Response::Error(msg) => Step::Error(msg),
        _ => Step::Other,
    }
}

/// The fixed request script every matrix cell runs: inserts, work, two
/// seals (copy chunks cross twice), a flatten snapshot, point queries
/// and a stats read — 12 calls, all synchronous.
fn run_script(c: &Coordinator) -> Vec<Step> {
    let mut trace = Vec::new();
    for seed in 0..4u64 {
        trace.push(reduce(c.call(Request::Insert { values: batch(seed) })));
    }
    trace.push(reduce(c.call(Request::Work { calls: 2 })));
    trace.push(reduce(c.call(Request::Seal)));
    for seed in 4..6u64 {
        trace.push(reduce(c.call(Request::Insert { values: batch(seed) })));
    }
    trace.push(reduce(c.call(Request::Flatten)));
    trace.push(reduce(c.call(Request::Seal)));
    trace.push(reduce(c.call(Request::Query { index: 0 })));
    trace.push(reduce(c.call(Request::Query { index: 700 })));
    trace.push(reduce(c.call(Request::Stats)));
    trace
}

/// Post-fault probes: the store must keep serving after any contained
/// fault. Returns the final snapshot for ledger assertions.
fn probe_recovery(c: &Coordinator, site: &'static str, nth: u64) -> MetricsSnapshot {
    let r = c.call(Request::Insert { values: batch(99) });
    assert!(
        matches!(r, Response::Inserted { count: 256, .. }),
        "[{site} nth={nth}] post-fault insert failed: {r:?}"
    );
    let r = c.call(Request::Seal);
    assert!(matches!(r, Response::Sealed { .. }), "[{site} nth={nth}] post-fault seal failed: {r:?}");
    let r = c.call(Request::Query { index: 0 });
    assert!(
        matches!(r, Response::Value(Some(_))),
        "[{site} nth={nth}] post-fault query failed: {r:?}"
    );
    match c.call(Request::Stats) {
        Response::Stats(s) => s,
        other => panic!("[{site} nth={nth}] post-fault stats failed: {other:?}"),
    }
}

#[test]
fn chaos_matrix_every_site_upholds_its_contract() {
    let _x = exclusive();
    faults::quiet_panic_hook();
    for &(shards, execs) in &[(1usize, 1usize), (1, 4), (4, 1), (4, 4)] {
        let config = cfg(shards, execs);
        // Fault-free oracle for this geometry (no plan armed).
        let oracle = {
            let c = Coordinator::start(config.clone());
            let t = run_script(&c);
            c.shutdown();
            t
        };
        assert!(
            !oracle.iter().any(|s| matches!(s, Step::Failed(_) | Step::Error(_))),
            "oracle run must be clean ({shards} shards, {execs} executors): {oracle:?}"
        );

        for site in SITES {
            for nth in [1u64, 2] {
                // Arm before construction: Degrade sites cross during the
                // scheduler's startup spawns.
                let guard = FaultPlan { site: site.name, nth }.arm();
                let c = Coordinator::start(config.clone());
                let trace = run_script(&c);
                let fired = guard.fired();
                drop(guard); // disarm before the recovery probes

                let tag = format!(
                    "site={} nth={nth} shards={shards} execs={execs} fired={fired}",
                    site.name
                );
                match (fired, site.kind) {
                    (false, _) => {
                        // Arm (b): an unfired plan must not perturb a bit.
                        assert_eq!(trace, oracle, "[{tag}] unfired plan changed the trace");
                    }
                    (true, SiteKind::Degrade) => {
                        // No error surfaces; results byte-identical; the
                        // lost worker is ledgered.
                        assert_eq!(trace, oracle, "[{tag}] degraded run diverged from oracle");
                        let s = probe_recovery(&c, site.name, nth);
                        assert!(
                            s.degraded_workers >= 1 && s.spawn_failures >= 1,
                            "[{tag}] degrade not ledgered: {} degraded, {} spawn failures",
                            s.degraded_workers,
                            s.spawn_failures
                        );
                    }
                    (true, SiteKind::Abort) => {
                        // At most one request observes the typed error
                        // (a fault inside a barrier drain is absorbed and
                        // only ledgered); everything else must succeed.
                        let failed = trace
                            .iter()
                            .filter(|s| matches!(s, Step::Failed(_)))
                            .count();
                        assert!(failed <= 1, "[{tag}] more than one failed step: {trace:?}");
                        assert!(
                            !trace.iter().any(|s| matches!(s, Step::Error(_))),
                            "[{tag}] untyped error leaked: {trace:?}"
                        );
                        let s = probe_recovery(&c, site.name, nth);
                        assert!(s.errors >= 1, "[{tag}] abort not ledgered in errors");
                        // Conservation: every resident element was counted
                        // applied, every aborted batch fully rolled back.
                        assert_eq!(
                            s.len, s.elements_inserted,
                            "[{tag}] ledger conservation broken: len {} vs inserted {}",
                            s.len, s.elements_inserted
                        );
                    }
                    (true, SiteKind::Fatal) => {
                        // The worker died mid-script: from the first
                        // ServiceDown on, every call reports it (never a
                        // hang — `Client::call` is probed by the script
                        // itself) and sessions close with payload back.
                        let first_down = trace
                            .iter()
                            .position(|s| matches!(s, Step::Failed(ExecError::ServiceDown)))
                            .unwrap_or_else(|| panic!("[{tag}] no ServiceDown in {trace:?}"));
                        for (i, step) in trace.iter().enumerate().skip(first_down) {
                            assert!(
                                matches!(step, Step::Failed(ExecError::ServiceDown)),
                                "[{tag}] step {i} after worker death was {step:?}"
                            );
                        }
                        assert!(
                            matches!(c.call(Request::Stats), Response::Failed(ExecError::ServiceDown)),
                            "[{tag}] dead service answered stats"
                        );
                        let mut sess = c.session();
                        let payload = batch(7);
                        match sess.try_insert(payload.clone()) {
                            Admission::Closed { values } => assert_eq!(values, payload),
                            other => panic!("[{tag}] session on dead service: {other:?}"),
                        }
                    }
                }
                c.shutdown();
            }
        }
    }
}

// ---------------------------------------------------------------------
// Acceptance criterion, end to end: a mid-chunk worker panic aborts the
// in-flight op with a typed error and the store keeps serving.
// ---------------------------------------------------------------------

#[test]
fn smoke_mid_chunk_panic_store_keeps_serving() {
    let _x = exclusive();
    faults::quiet_panic_hook();
    let c = Coordinator::start(cfg(4, 4));
    for seed in 0..4u64 {
        let r = c.call(Request::Insert { values: batch(seed) });
        assert!(matches!(r, Response::Inserted { count: 256, .. }), "warm insert failed: {r:?}");
    }

    let guard = FaultPlan::first("scheduler.worker.fill").arm();
    let r = c.call(Request::Insert { values: batch(4) });
    assert!(guard.fired(), "scheduled insert dispatch must cross the fill site");
    drop(guard);
    assert!(
        matches!(r, Response::Failed(ExecError::ChunkPanic { op: "insert", .. })),
        "faulted insert response: {r:?}"
    );

    // Subsequent Insert / Seal / Query all succeed, and the ledger shows
    // exactly one aborted batch: 5 batches accepted, 4 + 1 post-fault
    // applied, len == elements_inserted.
    let s = probe_recovery(&c, "scheduler.worker.fill", 1);
    assert_eq!(s.len, 5 * 256, "one batch aborted, five landed");
    assert_eq!(s.len, s.elements_inserted);
    assert_eq!(s.errors, 1);
    assert!(s.worker_respawns >= 1, "panicked scheduler worker was not respawned");
    let r = c.call(Request::Query { index: s.len - 1 });
    assert!(matches!(r, Response::Value(Some(_))));
    c.shutdown();
}
