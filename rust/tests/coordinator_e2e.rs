//! End-to-end coordinator tests: the full request path — routing,
//! batching, PJRT work kernels (when artifacts exist), flatten — with
//! numeric verification against host-computed expectations.

use std::time::Duration;

use ggarray::coordinator::batcher::BatchConfig;
use ggarray::coordinator::frontend::FrontendConfig;
use ggarray::coordinator::request::{checksum, Request, Response};
use ggarray::coordinator::router::Policy;
use ggarray::coordinator::service::{Coordinator, CoordinatorConfig};
use ggarray::insertion::InsertionKind;
use ggarray::runtime::ArtifactManifest;
use ggarray::sim::spec::DeviceSpec;

fn cfg(blocks: usize, use_artifacts: bool) -> CoordinatorConfig {
    CoordinatorConfig {
        device: DeviceSpec::a100(),
        blocks,
        first_bucket_size: 32,
        insertion: InsertionKind::WarpScan,
        routing: Policy::Even,
        batch: BatchConfig { max_values: 2048, max_delay: Duration::from_millis(1) },
        use_artifacts,
        work_iters: 30,
        heap_capacity: None,
        epoch_heap: None,
        shards: 1,
        compact_segments: 4,
        executor_threads: 0,
        frontend: FrontendConfig::default(),
    }
}

/// Host-side expectation of the full pipeline: even-routed inserts,
/// block-major flatten order, `calls` work passes.
fn expected_flat(blocks: usize, batches: &[Vec<f32>], work_calls: u32) -> Vec<f32> {
    let mut per_block: Vec<Vec<f32>> = vec![Vec::new(); blocks];
    for values in batches {
        let n = values.len();
        let counts: Vec<usize> = (0..blocks).map(|i| n / blocks + usize::from(i < n % blocks)).collect();
        let mut off = 0;
        for (b, &c) in counts.iter().enumerate() {
            per_block[b].extend_from_slice(&values[off..off + c]);
            off += c;
        }
    }
    let mut flat: Vec<f32> = per_block.into_iter().flatten().collect();
    for _ in 0..work_calls {
        for v in &mut flat {
            // 30 sequential f32 adds, matching kernel semantics exactly.
            for _ in 0..30 {
                *v += 1.0;
            }
        }
    }
    flat
}

fn run_pipeline(use_artifacts: bool) -> (u64, u64, u64) {
    let blocks = 8;
    let c = Coordinator::start(cfg(blocks, use_artifacts));
    // Batches big enough to flush by size (2048) plus a deadline tail.
    let batch_a: Vec<f32> = (0..2048).map(|i| i as f32).collect();
    let batch_b: Vec<f32> = (0..1000).map(|i| (i * 3) as f32).collect();
    c.call(Request::Insert { values: batch_a.clone() });
    c.call(Request::Insert { values: batch_b.clone() });
    c.call(Request::Work { calls: 2 });
    let (len, sum, pjrt) = match c.call(Request::Flatten) {
        Response::Flattened { len, checksum, .. } => {
            let stats = match c.call(Request::Stats) {
                Response::Stats(s) => s,
                other => panic!("{other:?}"),
            };
            (len, checksum, stats.pjrt_executions)
        }
        other => panic!("{other:?}"),
    };
    // Expected flat contents. NOTE: the coordinator flushes `batch_a` by
    // size (2048 = max_values) and `batch_b` at the Work barrier, so the
    // two batches are routed independently — same as here.
    let want = expected_flat(blocks, &[batch_a, batch_b], 2);
    assert_eq!(len, want.len() as u64);
    assert_eq!(sum, checksum(&want), "flatten contents mismatch (artifacts={use_artifacts})");
    c.shutdown();
    (len, sum, pjrt)
}

#[test]
fn pipeline_host_fallback() {
    let (len, _, pjrt) = run_pipeline(false);
    assert_eq!(len, 3048);
    assert_eq!(pjrt, 0, "host fallback must not touch PJRT");
}

#[test]
fn pipeline_with_artifacts_matches_host_fallback() {
    if !ArtifactManifest::available() {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        return;
    }
    let (len_a, sum_a, pjrt) = run_pipeline(true);
    let (len_b, sum_b, _) = run_pipeline(false);
    assert_eq!((len_a, sum_a), (len_b, sum_b), "PJRT path and host path must agree bit-exactly");
    assert!(pjrt > 0, "artifact path should actually execute PJRT");
}

#[test]
fn routing_policies_preserve_multiset() {
    for policy in [Policy::Even, Policy::LeastLoaded, Policy::Hash] {
        let mut c = cfg(4, false);
        c.routing = policy;
        let coord = Coordinator::start(c);
        let values: Vec<f32> = (0..500).map(|i| i as f32).collect();
        coord.call(Request::Insert { values: values.clone() });
        let flat = match coord.call(Request::Flatten) {
            Response::Flattened { len, .. } => len,
            other => panic!("{other:?}"),
        };
        assert_eq!(flat, 500, "{policy:?}");
        // Every value must be present exactly once.
        let mut got: Vec<f32> = Vec::new();
        for i in 0..500u64 {
            got.push(coord.call(Request::Query { index: i }).expect_value().unwrap());
        }
        let mut sorted = got.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(sorted, values, "{policy:?}");
        coord.shutdown();
    }
}

#[test]
fn stats_reflect_pipeline() {
    let c = Coordinator::start(cfg(4, false));
    for _ in 0..10 {
        c.call(Request::Insert { values: vec![1.0; 100] });
    }
    c.call(Request::Work { calls: 1 });
    c.call(Request::Flatten);
    let s = match c.call(Request::Stats) {
        Response::Stats(s) => s,
        other => panic!("{other:?}"),
    };
    assert_eq!(s.elements_inserted, 1000);
    assert_eq!(s.len, 1000);
    assert_eq!(s.work_calls, 1);
    assert_eq!(s.flattens, 1);
    assert!(s.batches >= 1 && s.batches <= 10);
    assert!(s.sim_insert_ms > 0.0);
    assert!(s.sim_work_ms > 0.0);
    assert!(s.sim_flatten_ms > 0.0);
    assert!(s.mean_latency_us > 0.0);
    c.shutdown();
}

#[test]
fn concurrent_clients_conserve_elements() {
    // 8 client threads × 50 inserts of 64 values: nothing lost, nothing
    // duplicated, service stays healthy throughout.
    let coord = Coordinator::start(cfg(8, false));
    let threads = 8;
    let inserts_per_thread = 50;
    let chunk = 64usize;
    let mut handles = Vec::new();
    for t in 0..threads {
        let client = coord.client();
        handles.push(std::thread::spawn(move || {
            for k in 0..inserts_per_thread {
                let base = (t * 1_000_000 + k * chunk) as f32;
                let values: Vec<f32> = (0..chunk).map(|i| base + i as f32).collect();
                match client.call(Request::Insert { values }) {
                    Response::Inserted { count, .. } => assert_eq!(count, chunk as u64),
                    other => panic!("{other:?}"),
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    // Stats barriers pending batches itself.
    let s = match coord.call(Request::Stats) {
        Response::Stats(s) => s,
        other => panic!("{other:?}"),
    };
    let expect = (threads * inserts_per_thread * chunk) as u64;
    assert_eq!(s.elements_inserted, expect);
    assert_eq!(s.len, expect);
    assert_eq!(s.errors, 0);
    // All values present exactly once (multiset check via sum).
    let mut sum = 0f64;
    for i in 0..expect {
        sum += coord.call(Request::Query { index: i }).expect_value().unwrap() as f64;
    }
    let want_sum: f64 = (0..threads)
        .flat_map(|t| (0..inserts_per_thread * chunk).map(move |j| (t * 1_000_000 + j) as f64))
        .sum();
    assert_eq!(sum, want_sum);
    coord.shutdown();
}

#[test]
fn oom_injection_degrades_gracefully() {
    // A 64 KiB VRAM budget: the service must report errors, keep a
    // consistent index, and keep serving queries/stats after the OOM.
    let mut c = cfg(4, false);
    c.heap_capacity = Some(64 * 1024);
    let coord = Coordinator::start(c);
    // ~16k f32 fit; try to insert 40k.
    for _ in 0..40 {
        coord.call(Request::Insert { values: vec![1.5f32; 1000] });
    }
    // Stats barriers pending batches itself.
    let s = match coord.call(Request::Stats) {
        Response::Stats(s) => s,
        other => panic!("{other:?}"),
    };
    assert!(s.errors > 0, "expected simulated OOM errors");
    assert!(s.len < 40_000, "len {} should be capped by the budget", s.len);
    assert!(s.allocated_bytes <= 64 * 1024);
    // Service still serves reads and work after the failure.
    assert_eq!(coord.call(Request::Query { index: 0 }).expect_value(), Some(1.5));
    match coord.call(Request::Work { calls: 1 }) {
        Response::Worked { .. } => {}
        other => panic!("{other:?}"),
    }
    assert_eq!(coord.call(Request::Query { index: 0 }).expect_value(), Some(31.5));
    coord.shutdown();
}

#[test]
fn empty_array_operations_are_safe() {
    let c = Coordinator::start(cfg(2, false));
    match c.call(Request::Work { calls: 3 }) {
        Response::Worked { calls: 3, .. } => {}
        other => panic!("{other:?}"),
    }
    match c.call(Request::Flatten) {
        Response::Flattened { len: 0, .. } => {}
        other => panic!("{other:?}"),
    }
    assert_eq!(c.call(Request::Query { index: 0 }).expect_value(), None);
    c.shutdown();
}
