//! Flatten-order regression suite: `flatten` (and the new multi-shard
//! `flatten_concat`) must preserve global block-major order exactly as
//! reconstructable from `block_sizes()` / `even_split`, including under
//! adversarial per-block distributions — heavy skew, empty blocks, and
//! single-block pile-ups — where an off-by-one in bucket walking or
//! prefix indexing would scramble the output.

use ggarray::ggarray::array::{GgArray, GgConfig};
use ggarray::ggarray::flatten::{flatten, flatten_concat};
use ggarray::insertion::InsertionKind;
use ggarray::sim::spec::DeviceSpec;
use ggarray::util::rng::Rng;

fn cfg(blocks: usize) -> GgConfig {
    GgConfig {
        num_blocks: blocks,
        threads_per_block: 256,
        first_bucket_size: 4,
        insertion: InsertionKind::WarpScan,
    }
}

/// Push an explicit per-block distribution, returning the per-block
/// contents (the ground truth for block-major order).
fn fill_blocks(gg: &mut GgArray<u32>, dist: &[usize]) -> Vec<Vec<u32>> {
    let mut counter = 0u32;
    let mut truth: Vec<Vec<u32>> = Vec::with_capacity(dist.len());
    for (b, &n) in dist.iter().enumerate() {
        let chunk: Vec<u32> = (counter..counter + n as u32).collect();
        counter += n as u32;
        gg.push_bulk_to_block(b, &chunk).unwrap();
        truth.push(chunk);
    }
    gg.rebuild_index_charged();
    truth
}

#[test]
fn flatten_preserves_order_for_adversarial_distributions() {
    let distributions: Vec<Vec<usize>> = vec![
        // all data in one block, everything else empty
        vec![0, 0, 0, 5000, 0, 0, 0, 0],
        // empty blocks interleaved with tiny and huge ones
        vec![1, 0, 3000, 0, 1, 0, 2999, 0],
        // geometric skew
        vec![4096, 2048, 1024, 512, 256, 128, 64, 32],
        // boundary sizes around the bucket structure (fbs 4: 4, 12, 28…)
        vec![3, 4, 5, 11, 12, 13, 27, 28],
        // completely empty array
        vec![0, 0, 0, 0, 0, 0, 0, 0],
    ];
    for (d, dist) in distributions.iter().enumerate() {
        let mut gg: GgArray<u32> = GgArray::new(cfg(8), DeviceSpec::a100());
        let truth = fill_blocks(&mut gg, dist);
        // block_sizes must mirror the distribution exactly.
        let sizes: Vec<u64> = dist.iter().map(|&n| n as u64).collect();
        assert_eq!(gg.block_sizes(), sizes, "distribution {d}");
        let flat = flatten(&mut gg).unwrap();
        let want: Vec<u32> = truth.into_iter().flatten().collect();
        assert_eq!(flat.data, want, "distribution {d}: flatten broke block-major order");
        // And the prefix index agrees element by element.
        for (i, &v) in want.iter().enumerate() {
            assert_eq!(gg.get(i as u64), Some(v), "distribution {d}, index {i}");
        }
    }
}

#[test]
fn flatten_matches_even_split_reconstruction() {
    // The paper's even insertion path: multiple insert_bulk rounds, each
    // split per even_split. The flatten order must equal the per-block
    // reconstruction from those splits.
    let mut gg: GgArray<u32> = GgArray::new(cfg(8), DeviceSpec::a100());
    let mut per_block: Vec<Vec<u32>> = vec![Vec::new(); 8];
    let mut counter = 0u32;
    for round_size in [1usize, 7, 8, 100, 1023, 4096] {
        let vals: Vec<u32> = (counter..counter + round_size as u32).collect();
        counter += round_size as u32;
        let counts = gg.even_split(round_size);
        let mut off = 0;
        for (b, &c) in counts.iter().enumerate() {
            per_block[b].extend_from_slice(&vals[off..off + c]);
            off += c;
        }
        gg.insert_bulk(&vals, InsertionKind::WarpScan).unwrap();
    }
    let want: Vec<u32> = per_block.iter().flatten().copied().collect();
    let sizes: Vec<u64> = per_block.iter().map(|v| v.len() as u64).collect();
    assert_eq!(gg.block_sizes(), sizes);
    let flat = flatten(&mut gg).unwrap();
    assert_eq!(flat.data, want);
}

#[test]
fn flatten_concat_equals_single_array_for_adversarial_shards() {
    // S shards × (B/S) blocks fed the same per-block distribution as one
    // B-block array must concatenate to byte-identical flat contents —
    // the invariant the sharded coordinator's seal path relies on —
    // including when whole shards are empty.
    let distributions: Vec<Vec<usize>> = vec![
        vec![0, 0, 0, 0, 900, 0, 0, 0],     // one shard holds everything
        vec![7, 0, 0, 0, 0, 0, 0, 1],       // first and last blocks only
        vec![128, 64, 32, 16, 8, 4, 2, 1],  // skew across shard boundary
        vec![0, 0, 0, 0, 0, 0, 0, 0],       // all shards empty
    ];
    for (d, dist) in distributions.iter().enumerate() {
        let mut single: GgArray<u32> = GgArray::new(cfg(8), DeviceSpec::a100());
        let truth = fill_blocks(&mut single, dist);
        let want: Vec<u32> = truth.into_iter().flatten().collect();
        let flat_single = flatten(&mut single).unwrap();
        assert_eq!(flat_single.data, want, "distribution {d}");
        for shards in [1usize, 2, 4] {
            let bps = 8 / shards;
            let mut parts: Vec<GgArray<u32>> =
                (0..shards).map(|_| GgArray::new(cfg(bps), DeviceSpec::a100())).collect();
            let mut counter = 0u32;
            for (b, &n) in dist.iter().enumerate() {
                let chunk: Vec<u32> = (counter..counter + n as u32).collect();
                counter += n as u32;
                parts[b / bps].push_bulk_to_block(b % bps, &chunk).unwrap();
            }
            let sharded = flatten_concat(&mut parts).unwrap();
            assert_eq!(sharded.data, want, "distribution {d}, {shards} shards");
            assert_eq!(sharded.shards(), shards);
            // Shard starts must equal the block-size prefix at shard
            // boundaries.
            let mut acc = 0u64;
            for s in 0..shards {
                assert_eq!(sharded.shard_start(s), acc, "distribution {d}, shard {s}");
                acc += dist[s * bps..(s + 1) * bps].iter().map(|&n| n as u64).sum::<u64>();
            }
            // locate() round-trips every element to its owning shard.
            for i in 0..want.len() as u64 {
                let (s, local) = sharded.locate(i).unwrap();
                assert_eq!(sharded.shard_start(s) + local, i);
            }
            assert_eq!(sharded.locate(want.len() as u64), None);
        }
    }
}

#[test]
fn flatten_concat_randomised_against_shadow() {
    // Randomised sweep: arbitrary per-block loads across 1/2/4 shards
    // must always equal the shadow reconstruction.
    let mut rng = Rng::new(0xF1A77E);
    for case in 0..20 {
        let dist: Vec<usize> = (0..8).map(|_| rng.below(600) as usize).collect();
        let want: Vec<u32> = {
            let mut acc = Vec::new();
            let mut counter = 0u32;
            for &n in &dist {
                acc.extend(counter..counter + n as u32);
                counter += n as u32;
            }
            acc
        };
        for shards in [2usize, 4] {
            let bps = 8 / shards;
            let mut parts: Vec<GgArray<u32>> =
                (0..shards).map(|_| GgArray::new(cfg(bps), DeviceSpec::a100())).collect();
            let mut counter = 0u32;
            for (b, &n) in dist.iter().enumerate() {
                let chunk: Vec<u32> = (counter..counter + n as u32).collect();
                counter += n as u32;
                parts[b / bps].push_bulk_to_block(b % bps, &chunk).unwrap();
            }
            let sharded = flatten_concat(&mut parts).unwrap();
            assert_eq!(sharded.data, want, "case {case}, {shards} shards");
        }
    }
}
