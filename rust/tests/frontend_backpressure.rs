//! Backpressure contract of the admission frontend: a full bounded
//! client channel sheds with a typed rejection — payload handed back,
//! no panic, no unbounded queue growth, no silent drop — the queue
//! drains at the next sync point, subsequent requests succeed, and the
//! `shed_requests` ledger in `Stats` matches exactly the rejections the
//! clients observed.

use std::time::Duration;

use ggarray::coordinator::frontend::{FrontendConfig, MergePolicy};
use ggarray::coordinator::request::{Admission, Request};
use ggarray::coordinator::service::{Coordinator, CoordinatorConfig};

fn cfg(queue_requests: usize, merge: MergePolicy) -> CoordinatorConfig {
    CoordinatorConfig {
        blocks: 8,
        shards: 1,
        first_bucket_size: 16,
        use_artifacts: false,
        frontend: FrontendConfig {
            queue_requests,
            retry_after: Duration::from_micros(50),
            merge,
        },
        ..CoordinatorConfig::default()
    }
}

#[test]
fn full_channel_sheds_typed_then_drains_and_recovers() {
    // AtBarrier: nothing drains until a sync point, so the 4-deep window
    // fills deterministically.
    let c = Coordinator::start(cfg(4, MergePolicy::AtBarrier));
    let mut s = c.session();

    // Fill the window: 4 accepted requests, gap-free sequence numbers,
    // running value ledger.
    for i in 0..4u64 {
        let (seq, session_values) = s.try_insert(vec![i as f32; 8]).expect_accepted();
        assert_eq!(seq, i);
        assert_eq!(session_values, (i + 1) * 8);
    }

    // Overflow: typed rejection every time — payload returned intact,
    // positive retry hint, and NO sequence number consumed.
    for _ in 0..3 {
        match s.try_insert(vec![99.0; 8]) {
            Admission::Rejected { retry_after_hint, values } => {
                assert!(retry_after_hint > Duration::ZERO);
                assert_eq!(values, vec![99.0; 8], "rejected payload must come back untouched");
            }
            other => panic!("expected Rejected on a full channel, got {other:?}"),
        }
    }
    assert_eq!(s.next_seq(), 4, "rejections must not consume sequence numbers");
    assert_eq!(s.accepted_values(), 32);

    // Stats is a sync point: the window drains into the batcher and the
    // shed ledger matches the three rejections observed above.
    let snap = s.call(Request::Stats).expect_stats();
    assert_eq!(snap.len, 32, "all accepted values visible after the sync point");
    assert_eq!(snap.admitted_requests, 4);
    assert_eq!(snap.admitted_values, 32);
    assert_eq!(snap.shed_requests, 3);
    assert_eq!(snap.sessions, 1);
    assert_eq!(snap.errors, 0);

    // The drained window accepts again; the sequence resumes where the
    // accepted stream left off.
    let (seq, session_values) = s.try_insert(vec![7.0; 8]).expect_accepted();
    assert_eq!(seq, 4);
    assert_eq!(session_values, 40);
    let snap = s.call(Request::Stats).expect_stats();
    assert_eq!(snap.len, 40);
    assert_eq!(snap.shed_requests, 3, "recovery must not shed");
    c.shutdown();
}

#[test]
fn retrying_under_sustained_overload_loses_nothing() {
    // Eager merge, 2-deep window, single hot producer: the worker drains
    // on pokes, so insert_retrying always gets through eventually. Every
    // value must land exactly once and every observed rejection must be
    // ledgered.
    let c = Coordinator::start(cfg(2, MergePolicy::Eager));
    let mut s = c.session();
    let mut sheds_observed = 0u64;
    for i in 0..200u64 {
        // A generous attempt budget: the worker is live, so exhaustion
        // here would indicate a real livelock, not overload.
        let (adm, sheds) = s.insert_retrying(vec![i as f32; 16], 10_000);
        assert!(adm.is_accepted(), "request {i} must eventually be admitted: {adm:?}");
        sheds_observed += sheds;
    }
    let snap = s.call(Request::Stats).expect_stats();
    assert_eq!(snap.len, 200 * 16, "no accepted value may be dropped");
    assert_eq!(snap.admitted_requests, 200);
    assert_eq!(snap.admitted_values, 200 * 16);
    assert_eq!(
        snap.shed_requests, sheds_observed,
        "metrics shed ledger must match client-observed rejections"
    );
    assert_eq!(snap.errors, 0);
    c.shutdown();
}

#[test]
fn shed_ledger_aggregates_across_sessions() {
    let c = Coordinator::start(cfg(2, MergePolicy::AtBarrier));
    let mut s0 = c.session();
    let mut s1 = c.session();
    for s in [&mut s0, &mut s1] {
        // Fill the 2-deep window, then observe 2 rejections.
        for _ in 0..2 {
            assert!(s.try_insert(vec![1.0; 4]).is_accepted());
        }
        for _ in 0..2 {
            assert!(
                matches!(s.try_insert(vec![2.0; 4]), Admission::Rejected { .. }),
                "window full: expected a typed rejection"
            );
        }
    }
    let snap = c.call(Request::Stats).expect_stats();
    assert_eq!(snap.sessions, 2);
    assert_eq!(snap.len, 16, "2 sessions × 2 accepted requests × 4 values");
    assert_eq!(snap.admitted_requests, 4);
    assert_eq!(snap.shed_requests, 4, "sheds from both sessions aggregate");
    c.shutdown();
}
