//! Cross-module integration: the paper's workloads on *real* (small)
//! data through every structure, checking both semantics and
//! cost-model shape.

use ggarray::baselines::{memmap::MemMapArray, semistatic::SemiStaticArray, static_array::StaticArray, GrowableArray};
use ggarray::ggarray::array::{GgArray, GgConfig};
use ggarray::ggarray::flatten::flatten;
use ggarray::insertion::InsertionKind;
use ggarray::sim::spec::DeviceSpec;
use ggarray::workload::{synth_values, Step, WorkloadSpec};

/// Drive a WorkloadSpec through a GrowableArray, returning (final_len,
/// checksum of contents).
fn drive(s: &mut dyn GrowableArray<u32>, w: &WorkloadSpec) -> (usize, u64) {
    let mut counter = 0u64;
    for step in &w.steps {
        match step {
            Step::Insert(n) => {
                let vals = synth_values(counter, *n as usize);
                counter += *n;
                s.grow_for(vals.len()).unwrap();
                s.insert_bulk(&vals, InsertionKind::WarpScan).unwrap();
            }
            Step::Work(calls) => {
                for _ in 0..*calls {
                    s.read_write(30.0, &mut |x| *x = x.wrapping_add(30));
                }
            }
            Step::Flatten | Step::Seal => {} // flat structures are already flat
        }
    }
    let mut h = 0xcbf29ce484222325u64;
    for i in 0..s.len() as u64 {
        h ^= s.get(i).unwrap() as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    (s.len(), h)
}

#[test]
fn all_structures_agree_on_duplication_workload() {
    let spec = DeviceSpec::a100();
    let w = WorkloadSpec::duplication(500, 4); // 500 → 8000 elements
    let mut st: StaticArray<u32> = StaticArray::new(spec.clone(), 20_000);
    let mut semi: SemiStaticArray<u32> = SemiStaticArray::new(spec.clone(), 16);
    let mut mm: MemMapArray<u32> = MemMapArray::new(spec.clone(), 1 << 24);
    let (l1, c1) = drive(&mut st, &w);
    let (l2, c2) = drive(&mut semi, &w);
    let (l3, c3) = drive(&mut mm, &w);
    assert_eq!(l1, w.expected_final as usize);
    assert_eq!((l1, c1), (l2, c2));
    assert_eq!((l1, c1), (l3, c3));
}

#[test]
fn ggarray_matches_baselines_content() {
    let spec = DeviceSpec::a100();
    let w = WorkloadSpec::duplication(300, 3);
    let mut st: StaticArray<u32> = StaticArray::new(spec.clone(), 10_000);
    let (_, want) = drive(&mut st, &w);

    let mut gg: GgArray<u32> =
        GgArray::new(GgConfig { num_blocks: 8, threads_per_block: 256, first_bucket_size: 16, insertion: InsertionKind::WarpScan }, spec);
    let mut counter = 0u64;
    for step in &w.steps {
        match step {
            Step::Insert(n) => {
                let vals = synth_values(counter, *n as usize);
                counter += *n;
                let split = gg.even_split(vals.len());
                gg.grow_for(&split).unwrap();
                gg.insert_bulk(&vals, InsertionKind::WarpScan).unwrap();
            }
            Step::Work(calls) => {
                for _ in 0..*calls {
                    gg.read_write_block(30.0, |x| *x = x.wrapping_add(30));
                }
            }
            Step::Flatten | Step::Seal => {}
        }
    }
    // NOTE: GGArray's global order is block-major (each insert splits
    // evenly), which differs from the flat append order — so compare
    // multisets + length, and spot-check via per-block reconstruction.
    assert_eq!(gg.len(), w.expected_final as usize);
    let mut flat_gg = gg.to_vec();
    let mut flat_static: Vec<u32> = {
        let mut st: StaticArray<u32> = StaticArray::new(DeviceSpec::a100(), 10_000);
        let (_, _) = drive(&mut st, &w);
        (0..st.len() as u64).map(|i| st.get(i).unwrap()).collect()
    };
    flat_gg.sort_unstable();
    flat_static.sort_unstable();
    assert_eq!(flat_gg, flat_static);
    let _ = want;
}

#[test]
fn two_phase_flatten_then_work_is_equivalent() {
    // The paper's §VI.D pattern: grow in GGArray, flatten, run work on the
    // static copy — results must equal running work in place.
    let spec = DeviceSpec::a100();
    let cfg = GgConfig { num_blocks: 4, threads_per_block: 256, first_bucket_size: 8, insertion: InsertionKind::WarpScan };
    let data = synth_values(0, 5000);

    let mut gg_a: GgArray<u32> = GgArray::new(cfg.clone(), spec.clone());
    gg_a.insert_bulk(&data, InsertionKind::WarpScan).unwrap();
    gg_a.read_write_block(30.0, |x| *x = x.wrapping_add(30));
    let in_place: Vec<u32> = gg_a.to_vec();

    let mut gg_b: GgArray<u32> = GgArray::new(cfg, spec.clone());
    gg_b.insert_bulk(&data, InsertionKind::WarpScan).unwrap();
    let flat = flatten(&mut gg_b).unwrap();
    let mut st: StaticArray<u32> = StaticArray::new(spec, 8192);
    st.fill_from(&flat.data).unwrap();
    st.read_write(30.0, &mut |x| *x = x.wrapping_add(30));
    let via_flatten: Vec<u32> = (0..st.len() as u64).map(|i| st.get(i).unwrap()).collect();

    assert_eq!(in_place, via_flatten);
}

#[test]
fn simulated_times_have_paper_ordering_at_small_scale() {
    // Even at test scale the cost model must preserve the qualitative
    // Fig 5 relations: gg rw ≫ static rw; memMap grow ≪ semi-static grow.
    let spec = DeviceSpec::a100();
    // Big enough that kernel-launch latency doesn't dominate the modeled
    // times (at 2e5 elements the 3.5 µs launch hides the bandwidth gap).
    let n = 2_000_000;
    let data = synth_values(0, n);

    let mut st: StaticArray<u32> = StaticArray::new(spec.clone(), 2 * n);
    st.insert_bulk(&data, InsertionKind::WarpScan).unwrap();
    let t_rw_static = st.read_write(30.0, &mut |x| *x += 1).us;

    let mut gg: GgArray<u32> = GgArray::new(GgConfig::new(512), spec.clone());
    gg.insert_bulk(&data, InsertionKind::WarpScan).unwrap();
    let t_rw_gg = gg.read_write_block(30.0, |x| *x += 1).us;
    assert!(t_rw_gg > 5.0 * t_rw_static, "gg rw {t_rw_gg} vs static {t_rw_static}");

    let mut semi: SemiStaticArray<u32> = SemiStaticArray::new(spec.clone(), n);
    semi.insert_bulk(&data, InsertionKind::WarpScan).unwrap();
    let t_semi_grow = semi.grow_for(n).unwrap().us;
    let mut mm: MemMapArray<u32> = MemMapArray::new(spec, 1 << 30);
    mm.insert_bulk(&data, InsertionKind::WarpScan).unwrap();
    let t_mm_grow = mm.grow_for(n).unwrap().us;
    assert!(t_mm_grow < t_semi_grow, "memMap grow {t_mm_grow} vs semi {t_semi_grow}");
}

#[test]
fn memory_accounting_2x_bound_through_workload() {
    let spec = DeviceSpec::a100();
    let mut gg: GgArray<u32> =
        GgArray::new(GgConfig { num_blocks: 16, threads_per_block: 256, first_bucket_size: 16, insertion: InsertionKind::WarpScan }, spec);
    let mut counter = 0u64;
    for round in 0..8 {
        // Start well above the B·fbs first-bucket floor (16×16 = 256
        // slots) so the 2× doubling bound is the binding constraint.
        let n = gg.len().max(1000);
        let vals = synth_values(counter, n);
        counter += n as u64;
        gg.insert_bulk(&vals, InsertionKind::WarpScan).unwrap();
        let ratio = gg.overhead_ratio();
        assert!(ratio < 2.2, "round {round}: overhead {ratio}");
        // Heap accounting must agree with structure accounting.
        assert_eq!(gg.heap().used(), gg.allocated_bytes());
    }
}

#[test]
fn static_oom_where_ggarray_survives() {
    // The Fig 3 story as an executable test: under a tight VRAM budget an
    // uncertain workload kills the static array but not GGArray.
    let spec = DeviceSpec::a100();
    let budget = 64 * 1024u64; // 64 KiB
    // Static must provision p99 = ~10.24× base for σ=1 → OOM at alloc.
    let base = 4096usize; // 16 KiB of u32
    let p99 = (base as f64 * 10.24) as usize;
    assert!(StaticArray::<u32>::try_new(spec.clone(), p99, budget).is_err());
    // GGArray grows to the *actual* size (say 1.8× base) within budget.
    let actual = (base as f64 * 1.8) as usize;
    let heap = ggarray::sim::memory::VramHeap::with_capacity(spec.clone(), budget);
    let mut gg: GgArray<u32> = GgArray::with_heap(
        GgConfig { num_blocks: 4, threads_per_block: 256, first_bucket_size: 64, insertion: InsertionKind::WarpScan },
        spec,
        heap,
    );
    gg.insert_bulk(&synth_values(0, actual), InsertionKind::WarpScan).unwrap();
    assert_eq!(gg.len(), actual);
}
