#![cfg(ggcheck)]
//! Exhaustive bounded-interleaving model checks for the coordinator's
//! concurrency protocols (`RUSTFLAGS='--cfg ggcheck' cargo test --test
//! model_check`; wired as a ci.sh stage).
//!
//! Under `--cfg ggcheck` the `ggarray::sync` facade swaps std's
//! primitives for instrumented ones driven by `ggarray::checker` — a
//! loom-style DFS over yield points that runs the model closure once
//! per schedule and enumerates *every* bounded interleaving (each test
//! asserts `report.complete`). A failing schedule panics with a
//! replayable seed; `failure_seed_replays_deterministically` proves the
//! seed → schedule round trip on a deliberately racy model.
//!
//! Five protocols are checked, mirroring the crate's real
//! concurrency surface:
//!
//! 1. the work-stealing scheduler's park/unpark/steal/termination
//!    protocol on its shared monitor (no lost wakeup, termination only
//!    when the bucket is drained AND every worker is parked, and
//!    steal order never reorders per-slot results),
//! 2. the scheduler's panic containment: a job payload that panics
//!    kills its worker but never the phase — termination re-anchors on
//!    the shrunk live set (`parked == live`), the survivors (or the
//!    coordinator's inline floor-1 drain) finish the bucket, `finish`
//!    heals the group, and the respawned worker still receives the
//!    next phase's wakeup — in every interleaving,
//! 3. the admission window's shed path (a `Rejected` admission rolls
//!    back the pooled-values gauge and consumes no sequence number
//!    under every interleaving),
//! 4. the `AtBarrier` drain order (client-id ascending, per-client
//!    FIFO, independent of admission timing),
//! 5. the service supervisor's detect → respawn → replay handshake
//!    (`coordinator::supervisor`): the record-before-fault /
//!    clear-after-ack discipline yields exactly-once replay — no lost
//!    and no doubled request, every caller acked — in every
//!    interleaving of client sends, the loop death, and the failover.

use ggarray::checker::{self, Config};
use ggarray::coordinator::frontend::{FrontendConfig, FrontendRig, MergePolicy};
use ggarray::coordinator::request::Admission;
use ggarray::coordinator::scheduler::WorkerGroup;
use ggarray::sync::atomic::{AtomicUsize, Ordering};
use ggarray::sync::{mpsc, thread, Arc, SendSliceMut};

// ---------------- protocol 1: work-stealing scheduler ----------------

#[test]
fn scheduler_monitor_has_no_lost_wakeups() {
    // Two back-to-back phases against one worker: the second inject
    // races the worker's park decision after the first phase drains.
    // A lost wakeup (inject observed as pending but the epoch bump
    // missed between the worker's rescan and its wait) would deadlock
    // `finish`, which the checker reports as a hung schedule.
    let report = checker::check("scheduler-lost-wakeup", &Config::default(), || {
        let hits = Arc::new(AtomicUsize::new(0));
        let sink = Arc::clone(&hits);
        let group = WorkerGroup::new(1, move |j: usize| {
            sink.fetch_add(j, Ordering::SeqCst);
        });
        for round in 1..=2usize {
            let mut phase = group.phase();
            phase.inject(round);
            phase.finish();
        }
        assert_eq!(hits.load(Ordering::SeqCst), 3, "a phase lost its job");
        drop(group);
    })
    .unwrap_or_else(|failure| panic!("{failure}"));
    assert!(report.complete, "lost-wakeup exploration must exhaust its schedules");
    assert!(report.schedules >= 2, "protocol has real concurrency to explore");
}

#[test]
fn scheduler_termination_needs_drained_bucket_and_parked_worker() {
    // `finish` returns only once pending == 0 AND every worker is
    // parked. If it ever returned with a job still queued or running,
    // the counter below would read < 2 in some schedule.
    let report = checker::check("scheduler-termination", &Config::default(), || {
        let done = Arc::new(AtomicUsize::new(0));
        let sink = Arc::clone(&done);
        let group = WorkerGroup::new(1, move |_: usize| {
            sink.fetch_add(1, Ordering::SeqCst);
        });
        let mut phase = group.phase();
        phase.inject(0);
        phase.inject(1);
        phase.finish();
        assert_eq!(done.load(Ordering::SeqCst), 2, "finish returned before the bucket drained");
        let counters = group.counters();
        assert_eq!(counters.executed, 2, "ledger must agree with the barrier");
        assert!(counters.parks >= 1, "the worker must be parked when finish returns");
        drop(group);
    })
    .unwrap_or_else(|failure| panic!("{failure}"));
    assert!(report.complete, "termination exploration must exhaust its schedules");
    assert!(report.schedules >= 2);
}

#[test]
fn steal_order_never_reorders_per_slot_commits() {
    // Two workers, two jobs, each writing its own disjoint slot (the
    // scheduler's chunk commit discipline in miniature): whichever
    // worker executes or steals which job, slot k must end up holding
    // k's result — results are committed by position, never by
    // completion order.
    let report = checker::check(
        "scheduler-steal-commit-order",
        &Config { max_schedules: 500_000, ..Config::default() },
        || {
            let group = WorkerGroup::new(2, move |(slot, val): (SendSliceMut<usize>, usize)| {
                // SAFETY: each job owns a disjoint split_at_mut carve of
                // the phase-local buffer, and the submitter blocks in
                // finish() until every job completes.
                let slot = unsafe { slot.as_mut_slice() };
                slot[0] = val;
            });
            let mut buf = [0usize; 2];
            {
                let (a, b) = buf.split_at_mut(1);
                let mut phase = group.phase();
                phase.inject((SendSliceMut::new(a), 10));
                phase.inject((SendSliceMut::new(b), 20));
                phase.finish();
            }
            assert_eq!(buf, [10, 20], "steal order must never reorder per-slot results");
            drop(group);
        },
    )
    .unwrap_or_else(|failure| panic!("{failure}"));
    assert!(report.complete, "steal-order exploration must exhaust its schedules");
    assert!(report.schedules >= 2);
}

#[test]
fn scheduler_drop_while_idle_never_hangs() {
    // Shutdown racing the workers' very first park: every worker must
    // observe it whether the flag lands before or after parking.
    let report = checker::check("scheduler-idle-shutdown", &Config::default(), || {
        let group = WorkerGroup::new(2, |_: usize| {});
        drop(group); // must join both workers in every schedule
    })
    .unwrap_or_else(|failure| panic!("{failure}"));
    assert!(report.complete);
}

// ------------ protocol 2: panic containment and healing ------------

#[test]
fn contained_panic_drains_inline_and_heals_lone_worker() {
    // The lone worker dies on the poison job, so the group hits the
    // floor-1 case mid-phase: `finish` must observe `live == 0`, drain
    // the surviving chunk inline on the coordinator thread, terminate,
    // and heal. A termination check still comparing `parked` against
    // the spawn-time worker count (instead of `live`) would hang here,
    // which the checker reports as a stuck schedule.
    let report = checker::check("scheduler-panic-inline-drain", &Config::default(), || {
        ggarray::faults::quiet_panic_hook();
        let good = Arc::new(AtomicUsize::new(0));
        let sink = Arc::clone(&good);
        let group = WorkerGroup::new(1, move |j: usize| {
            if j == 0 {
                panic!("{} poison chunk", ggarray::faults::EXPECTED_PANIC);
            }
            sink.fetch_add(j, Ordering::SeqCst);
        });
        let mut phase = group.phase();
        phase.inject(0);
        phase.inject(7);
        let report = phase.finish();
        assert_eq!(report.failed, 1, "exactly the poison chunk fails");
        assert_eq!(good.load(Ordering::SeqCst), 7, "surviving chunk must still execute");
        // Healed: the respawned worker serves the next phase, so its
        // park/wakeup handshake must be live again.
        let mut phase = group.phase();
        phase.inject(5);
        assert!(phase.finish().ok());
        assert_eq!(good.load(Ordering::SeqCst), 12);
        drop(group);
    })
    .unwrap_or_else(|failure| panic!("{failure}"));
    assert!(report.complete, "inline-drain exploration must exhaust its schedules");
    assert!(report.schedules >= 2);
}

#[test]
fn contained_panic_with_survivor_terminates_and_heals() {
    // Two workers, one poison job: whichever worker pops (or steals) it
    // dies mid-phase. Termination must re-anchor on the shrunk live set
    // (`pending == 0 && parked == live`) — against the spawn count the
    // phase could never end; against a stale pending the phase could
    // end with the good job still queued. Both are schedule-dependent
    // bugs, so the assertion must hold in EVERY interleaving of pops,
    // steals, the death, and the survivor's park.
    let report = checker::check(
        "scheduler-panic-survivor",
        &Config { max_schedules: 500_000, ..Config::default() },
        || {
            ggarray::faults::quiet_panic_hook();
            let good = Arc::new(AtomicUsize::new(0));
            let sink = Arc::clone(&good);
            let group = WorkerGroup::new(2, move |j: usize| {
                if j == 0 {
                    panic!("{} poison chunk", ggarray::faults::EXPECTED_PANIC);
                }
                sink.fetch_add(j, Ordering::SeqCst);
            });
            let mut phase = group.phase();
            phase.inject(0);
            phase.inject(3);
            let report = phase.finish();
            assert_eq!(report.failed, 1, "exactly the poison chunk fails");
            assert_eq!(good.load(Ordering::SeqCst), 3, "the good chunk always lands");
            // `finish` healed the group: the next phase's wakeup must
            // reach the respawned worker as well as the survivor.
            let mut phase = group.phase();
            phase.inject(4);
            assert!(phase.finish().ok());
            assert_eq!(good.load(Ordering::SeqCst), 7);
            drop(group);
        },
    )
    .unwrap_or_else(|failure| panic!("{failure}"));
    assert!(report.complete, "survivor-containment exploration must exhaust its schedules");
    assert!(report.schedules >= 2);
}

// ---------------- protocol 3: admission shed rollback ----------------

#[test]
fn admission_shed_rollback_under_all_interleavings() {
    let report = checker::check("admission-shed-rollback", &Config::default(), || {
        let cfg = FrontendConfig {
            queue_requests: 1, // window of one: the second racy insert can shed
            merge: MergePolicy::AtBarrier,
            ..FrontendConfig::default()
        };
        let mut rig = FrontendRig::new(cfg);
        let mut session = rig.session();
        rig.absorb_registrations(); // pre-spawn, so registration is not part of the race
        assert_eq!(rig.lanes(), 1);

        let client = thread::spawn(move || {
            let mut accepted = 0u64;
            let mut rejected = 0u64;
            for i in 0..2u32 {
                match session.try_insert(vec![i as f32]) {
                    Admission::Accepted { seq, .. } => {
                        assert_eq!(seq, accepted, "accepted stream must be contiguous");
                        accepted += 1;
                    }
                    Admission::Rejected { values, .. } => {
                        assert_eq!(values.len(), 1, "payload must come back intact");
                        rejected += 1;
                    }
                    Admission::Closed { .. } => panic!("rig never closes the channel"),
                }
            }
            (session, accepted, rejected)
        });

        // One pressure sweep racing the client's two admissions (this
        // is what makes accept/accept vs accept/shed schedule-dependent).
        let mut moved = Vec::new();
        rig.drain(false, |id, ins| moved.push((id, ins.seq, ins.values.len())));
        let (session, accepted, rejected) = client.join().expect("client panicked");
        // Client quiesced: the barrier drain empties what remains.
        rig.drain(true, |id, ins| moved.push((id, ins.seq, ins.values.len())));

        // The ledgers must reconcile exactly in EVERY interleaving.
        assert_eq!(accepted + rejected, 2);
        assert_eq!(session.next_seq(), accepted, "a rejection consumes no sequence number");
        assert_eq!(rig.shared().shed_total(), rejected, "every shed lands in the ledger");
        assert_eq!(moved.len() as u64, accepted, "no lost or duplicated admission");
        assert_eq!(rig.shared().pooled_values(), 0, "pooled gauge must return to zero");
        for (k, &(id, seq, len)) in moved.iter().enumerate() {
            assert_eq!((id, len), (0, 1));
            assert_eq!(seq, k as u64, "worker-observed stream must be gap-free");
        }
    })
    .unwrap_or_else(|failure| panic!("{failure}"));
    assert!(report.complete, "shed-path exploration must exhaust its schedules");
    assert!(report.schedules >= 2);
}

// ---------------- protocol 4: AtBarrier drain order ----------------

#[test]
fn at_barrier_drain_orders_clients_ascending_fifo() {
    let report = checker::check("atbarrier-drain-order", &Config::default(), || {
        let cfg = FrontendConfig {
            queue_requests: 4, // wide enough that nothing sheds
            merge: MergePolicy::AtBarrier,
            ..FrontendConfig::default()
        };
        let mut rig = FrontendRig::new(cfg);
        let mut s0 = rig.session();
        let mut s1 = rig.session();
        rig.absorb_registrations();
        assert_eq!((s0.id(), s1.id(), rig.lanes()), (0, 1, 2));

        let c0 = thread::spawn(move || {
            for v in [1.0f32, 2.0] {
                assert!(s0.try_insert(vec![v]).is_accepted());
            }
        });
        let c1 = thread::spawn(move || {
            for v in [10.0f32, 20.0] {
                assert!(s1.try_insert(vec![v]).is_accepted());
            }
        });
        c0.join().expect("client 0 panicked");
        c1.join().expect("client 1 panicked");

        let mut merged = Vec::new();
        let stats = rig.drain(true, |id, ins| merged.push((id, ins.seq, ins.values[0])));
        assert_eq!(stats.moved_requests, 4);
        // However the two admission streams interleaved in wall time,
        // the barrier merge is a pure function of the per-client traces.
        assert_eq!(
            merged,
            vec![(0, 0, 1.0), (0, 1, 2.0), (1, 0, 10.0), (1, 1, 20.0)],
            "barrier merge must be client-id ascending with per-client FIFO"
        );
        assert_eq!(rig.shared().pooled_values(), 0);
    })
    .unwrap_or_else(|failure| panic!("{failure}"));
    assert!(report.complete, "drain-order exploration must exhaust its schedules");
    assert!(report.schedules >= 2);
}

// -------- protocol 5: supervisor detect → respawn → replay --------

/// The supervisor handshake in miniature, under every bounded
/// interleaving. Faults compile to no-ops under `ggcheck`, so the loop
/// death is modelled directly (one injected panic on the first
/// request's first attempt), while the protocol under test is the real
/// one from `coordinator::supervisor` / `service::Worker::serve`:
///
/// * the in-flight request is recorded BEFORE the fault point (before
///   any effect), and cleared only AFTER apply + ack;
/// * the supervisor catches the death (checker cancellation tokens
///   pass through), replays the recorded request exactly once over the
///   surviving state, and resumes serving.
///
/// Exactly-once is asserted from both sides: each request is applied
/// exactly once (no lost, no doubled replay) and each caller receives
/// exactly its own ack — whichever way the client's sends interleave
/// with the worker's receives, the death, and the failover.
#[test]
fn supervisor_replay_is_exactly_once_under_all_interleavings() {
    let report = checker::check("supervisor-detect-respawn-replay", &Config::default(), || {
        ggarray::faults::quiet_panic_hook();
        let applied = Arc::new([AtomicUsize::new(0), AtomicUsize::new(0)]);
        let log = Arc::clone(&applied);
        let (tx, rx) = mpsc::channel::<(usize, mpsc::Sender<usize>)>();

        let supervisor = thread::spawn(move || {
            let mut inflight: Option<(usize, mpsc::Sender<usize>)> = None;
            let mut armed = true; // the first handled request dies, once
            let (mut restarts, mut replays) = (0usize, 0usize);
            loop {
                let serve = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    while let Ok((req, reply)) = rx.recv() {
                        // Record before the fault point / any effect.
                        inflight = Some((req, reply.clone()));
                        if armed {
                            armed = false;
                            panic!("{} injected loop death", ggarray::faults::EXPECTED_PANIC);
                        }
                        log[req].fetch_add(1, Ordering::SeqCst);
                        let _ = reply.send(req);
                        // Clear only after apply + ack.
                        inflight = None;
                    }
                }));
                match serve {
                    Ok(()) => return (restarts, replays), // all senders gone
                    Err(payload) => {
                        if ggarray::checker::rt::cancelled() {
                            std::panic::resume_unwind(payload);
                        }
                        restarts += 1;
                        if let Some((req, reply)) = inflight.take() {
                            // Replay exactly once: the recorded request
                            // mutated nothing before the death.
                            replays += 1;
                            log[req].fetch_add(1, Ordering::SeqCst);
                            let _ = reply.send(req);
                        }
                    }
                }
            }
        });

        // Client: two requests racing the worker's receive/death/replay.
        let (ack0_tx, ack0_rx) = mpsc::channel();
        let (ack1_tx, ack1_rx) = mpsc::channel();
        tx.send((0, ack0_tx)).expect("send 0");
        tx.send((1, ack1_tx)).expect("send 1");
        drop(tx); // quiesce: the serve loop exits once drained

        // The caller is never left hanging and never mis-acked —
        // a dropped reply sender (lost request) would error here.
        assert_eq!(ack0_rx.recv().expect("request 0 lost"), 0, "mis-acked despite the death");
        assert_eq!(ack1_rx.recv().expect("request 1 lost"), 1, "mis-acked after the failover");

        let (restarts, replays) = supervisor.join().expect("supervisor panicked");
        assert_eq!(restarts, 1, "exactly one loop death");
        assert_eq!(replays, 1, "the un-acked request is replayed exactly once");
        assert_eq!(applied[0].load(Ordering::SeqCst), 1, "request 0: no lost, no doubled apply");
        assert_eq!(applied[1].load(Ordering::SeqCst), 1, "request 1: applied exactly once");
    })
    .unwrap_or_else(|failure| panic!("{failure}"));
    assert!(report.complete, "supervisor-handshake exploration must exhaust its schedules");
    assert!(report.schedules >= 2, "the handshake has real concurrency to explore");
}

// ---------------- meta: failure seeds replay ----------------

/// A deliberately racy read-modify-write on the facade atomics — the
/// canonical lost-update bug the checker exists to catch.
fn racy_gauge_model() {
    let gauge = Arc::new(AtomicUsize::new(0));
    let shared = Arc::clone(&gauge);
    let updater = thread::spawn(move || {
        let v = shared.load(Ordering::SeqCst);
        shared.store(v + 1, Ordering::SeqCst);
    });
    let v = gauge.load(Ordering::SeqCst);
    gauge.store(v + 1, Ordering::SeqCst);
    updater.join().expect("updater panicked");
    assert_eq!(gauge.load(Ordering::SeqCst), 2, "lost update");
}

#[test]
fn failure_seed_replays_deterministically() {
    let failure = checker::check("racy-gauge", &Config::default(), racy_gauge_model)
        .expect_err("the load/store race must be caught");
    assert!(
        failure.message.contains("lost update"),
        "unexpected failure mode: {}",
        failure.message
    );
    let seed = failure.seed();
    let replayed = checker::replay("racy-gauge", &seed, racy_gauge_model)
        .expect_err("the printed seed must reproduce the failure");
    assert!(replayed.message.contains("lost update"));
}
