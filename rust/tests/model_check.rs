#![cfg(ggcheck)]
//! Exhaustive bounded-interleaving model checks for the coordinator's
//! concurrency protocols (`RUSTFLAGS='--cfg ggcheck' cargo test --test
//! model_check`; wired as a ci.sh stage).
//!
//! Under `--cfg ggcheck` the `ggarray::sync` facade swaps std's
//! primitives for instrumented ones driven by `ggarray::checker` — a
//! loom-style DFS over yield points that runs the model closure once
//! per schedule and enumerates *every* bounded interleaving (each test
//! asserts `report.complete`). A failing schedule panics with a
//! replayable seed; `failure_seed_replays_deterministically` proves the
//! seed → schedule round trip on a deliberately racy model.
//!
//! Three protocols are checked, mirroring the crate's real
//! concurrency surface:
//!
//! 1. the SPSC mailbox handoff/barrier/shutdown used by the executor
//!    pool (no lost job, no result observed before the barrier),
//! 2. the admission window's shed path (a `Rejected` admission rolls
//!    back the pooled-values gauge and consumes no sequence number
//!    under every interleaving),
//! 3. the `AtBarrier` drain order (client-id ascending, per-client
//!    FIFO, independent of admission timing).

use ggarray::checker::{self, Config};
use ggarray::coordinator::frontend::{FrontendConfig, FrontendRig, MergePolicy};
use ggarray::coordinator::pool::Mailbox;
use ggarray::coordinator::request::Admission;
use ggarray::sync::atomic::{AtomicUsize, Ordering};
use ggarray::sync::{thread, Arc};

// ---------------- protocol 1: SPSC mailbox ----------------

#[test]
fn mailbox_handoff_barrier_shutdown_all_interleavings() {
    let report = checker::check("mailbox-handoff", &Config::default(), || {
        let mb = Arc::new(Mailbox::<u32, u32>::new());
        let exec = Arc::clone(&mb);
        let handle = thread::spawn(move || exec.executor_loop(|job| job * 2));
        // Two full submit → barrier-join cycles: join must return this
        // job's result (not stale, not early) in every schedule.
        mb.submit(21);
        assert_eq!(mb.join(), 42, "lost job or result read before barrier");
        mb.submit(7);
        assert_eq!(mb.join(), 14, "second handoff corrupted");
        mb.signal_shutdown();
        handle.join().expect("executor must exit cleanly after shutdown");
    })
    .unwrap_or_else(|failure| panic!("{failure}"));
    assert!(report.complete, "mailbox exploration must exhaust its schedules");
    assert!(report.schedules >= 2, "protocol has real concurrency to explore");
}

#[test]
fn mailbox_shutdown_while_idle_never_hangs() {
    let report = checker::check("mailbox-idle-shutdown", &Config::default(), || {
        let mb = Arc::new(Mailbox::<u32, u32>::new());
        let exec = Arc::clone(&mb);
        // Shutdown racing the executor's very first park: the executor
        // must observe it whether it arrives before or after parking.
        let handle = thread::spawn(move || exec.executor_loop(|job| job));
        mb.signal_shutdown();
        handle.join().expect("idle executor must exit on shutdown");
    })
    .unwrap_or_else(|failure| panic!("{failure}"));
    assert!(report.complete);
}

// ---------------- protocol 2: admission shed rollback ----------------

#[test]
fn admission_shed_rollback_under_all_interleavings() {
    let report = checker::check("admission-shed-rollback", &Config::default(), || {
        let cfg = FrontendConfig {
            queue_requests: 1, // window of one: the second racy insert can shed
            merge: MergePolicy::AtBarrier,
            ..FrontendConfig::default()
        };
        let mut rig = FrontendRig::new(cfg);
        let mut session = rig.session();
        rig.absorb_registrations(); // pre-spawn, so registration is not part of the race
        assert_eq!(rig.lanes(), 1);

        let client = thread::spawn(move || {
            let mut accepted = 0u64;
            let mut rejected = 0u64;
            for i in 0..2u32 {
                match session.try_insert(vec![i as f32]) {
                    Admission::Accepted { seq, .. } => {
                        assert_eq!(seq, accepted, "accepted stream must be contiguous");
                        accepted += 1;
                    }
                    Admission::Rejected { values, .. } => {
                        assert_eq!(values.len(), 1, "payload must come back intact");
                        rejected += 1;
                    }
                    Admission::Closed { .. } => panic!("rig never closes the channel"),
                }
            }
            (session, accepted, rejected)
        });

        // One pressure sweep racing the client's two admissions (this
        // is what makes accept/accept vs accept/shed schedule-dependent).
        let mut moved = Vec::new();
        rig.drain(false, |id, ins| moved.push((id, ins.seq, ins.values.len())));
        let (session, accepted, rejected) = client.join().expect("client panicked");
        // Client quiesced: the barrier drain empties what remains.
        rig.drain(true, |id, ins| moved.push((id, ins.seq, ins.values.len())));

        // The ledgers must reconcile exactly in EVERY interleaving.
        assert_eq!(accepted + rejected, 2);
        assert_eq!(session.next_seq(), accepted, "a rejection consumes no sequence number");
        assert_eq!(rig.shared().shed_total(), rejected, "every shed lands in the ledger");
        assert_eq!(moved.len() as u64, accepted, "no lost or duplicated admission");
        assert_eq!(rig.shared().pooled_values(), 0, "pooled gauge must return to zero");
        for (k, &(id, seq, len)) in moved.iter().enumerate() {
            assert_eq!((id, len), (0, 1));
            assert_eq!(seq, k as u64, "worker-observed stream must be gap-free");
        }
    })
    .unwrap_or_else(|failure| panic!("{failure}"));
    assert!(report.complete, "shed-path exploration must exhaust its schedules");
    assert!(report.schedules >= 2);
}

// ---------------- protocol 3: AtBarrier drain order ----------------

#[test]
fn at_barrier_drain_orders_clients_ascending_fifo() {
    let report = checker::check("atbarrier-drain-order", &Config::default(), || {
        let cfg = FrontendConfig {
            queue_requests: 4, // wide enough that nothing sheds
            merge: MergePolicy::AtBarrier,
            ..FrontendConfig::default()
        };
        let mut rig = FrontendRig::new(cfg);
        let mut s0 = rig.session();
        let mut s1 = rig.session();
        rig.absorb_registrations();
        assert_eq!((s0.id(), s1.id(), rig.lanes()), (0, 1, 2));

        let c0 = thread::spawn(move || {
            for v in [1.0f32, 2.0] {
                assert!(s0.try_insert(vec![v]).is_accepted());
            }
        });
        let c1 = thread::spawn(move || {
            for v in [10.0f32, 20.0] {
                assert!(s1.try_insert(vec![v]).is_accepted());
            }
        });
        c0.join().expect("client 0 panicked");
        c1.join().expect("client 1 panicked");

        let mut merged = Vec::new();
        let stats = rig.drain(true, |id, ins| merged.push((id, ins.seq, ins.values[0])));
        assert_eq!(stats.moved_requests, 4);
        // However the two admission streams interleaved in wall time,
        // the barrier merge is a pure function of the per-client traces.
        assert_eq!(
            merged,
            vec![(0, 0, 1.0), (0, 1, 2.0), (1, 0, 10.0), (1, 1, 20.0)],
            "barrier merge must be client-id ascending with per-client FIFO"
        );
        assert_eq!(rig.shared().pooled_values(), 0);
    })
    .unwrap_or_else(|failure| panic!("{failure}"));
    assert!(report.complete, "drain-order exploration must exhaust its schedules");
    assert!(report.schedules >= 2);
}

// ---------------- meta: failure seeds replay ----------------

/// A deliberately racy read-modify-write on the facade atomics — the
/// canonical lost-update bug the checker exists to catch.
fn racy_gauge_model() {
    let gauge = Arc::new(AtomicUsize::new(0));
    let shared = Arc::clone(&gauge);
    let updater = thread::spawn(move || {
        let v = shared.load(Ordering::SeqCst);
        shared.store(v + 1, Ordering::SeqCst);
    });
    let v = gauge.load(Ordering::SeqCst);
    gauge.store(v + 1, Ordering::SeqCst);
    updater.join().expect("updater panicked");
    assert_eq!(gauge.load(Ordering::SeqCst), 2, "lost update");
}

#[test]
fn failure_seed_replays_deterministically() {
    let failure = checker::check("racy-gauge", &Config::default(), racy_gauge_model)
        .expect_err("the load/store race must be caught");
    assert!(
        failure.message.contains("lost update"),
        "unexpected failure mode: {}",
        failure.message
    );
    let seed = failure.seed();
    let replayed = checker::replay("racy-gauge", &seed, racy_gauge_model)
        .expect_err("the printed seed must reproduce the failure");
    assert!(replayed.message.contains("lost update"));
}
