//! Property-based tests (testkit) over the coordinator-critical
//! invariants: routing conservation, unique slot assignment, prefix-index
//! correctness, LFVector capacity bounds, batcher conservation, VMM
//! accounting.

use ggarray::coordinator::batcher::BatchConfig;
use ggarray::coordinator::request::{Request, Response};
use ggarray::coordinator::router::{self, Policy};
use ggarray::coordinator::service::{Coordinator, CoordinatorConfig};
use ggarray::ggarray::index::PrefixIndex;
use ggarray::ggarray::lfvector::LfVector;
use ggarray::insertion::assign_indices;
use ggarray::sim::clock::Clock;
use ggarray::sim::memory::VramHeap;
use ggarray::sim::spec::DeviceSpec;
use ggarray::sim::vmm::{PhysicalPool, VmmRange};
use ggarray::testkit::{check, CountsVec, PairGen, U64Range, DEFAULT_CASES};
use ggarray::theory::memory_model::ggarray_capacity;
use ggarray::util::rng::Rng;

#[test]
fn prop_assign_indices_unique_dense() {
    let gen = CountsVec { max_len: 200, max_val: 50 };
    check("assign_indices unique+dense", 0xA11CE, DEFAULT_CASES, &gen, |counts| {
        let base = 1000u64;
        let (offsets, total) = assign_indices(base, counts);
        if offsets.len() != counts.len() {
            return Err("length mismatch".into());
        }
        let mut expanded: Vec<u64> = Vec::new();
        for (t, &c) in counts.iter().enumerate() {
            for k in 0..c {
                expanded.push(offsets[t] + k as u64);
            }
        }
        expanded.sort_unstable();
        let want: Vec<u64> = (base..total).collect();
        if expanded != want {
            return Err(format!("slots not dense: {expanded:?} != [{base},{total})"));
        }
        Ok(())
    });
}

#[test]
fn prop_router_conservation_and_bounds() {
    let gen = PairGen(CountsVec { max_len: 64, max_val: 1000 }, U64Range { lo: 0, hi: 5000 });
    check("router conserves elements", 0xB0B, DEFAULT_CASES, &gen, |(sizes_raw, n)| {
        if sizes_raw.is_empty() {
            return Ok(()); // router requires ≥1 block
        }
        let sizes: Vec<u64> = sizes_raw.iter().map(|&s| s as u64).collect();
        for policy in [Policy::Even, Policy::LeastLoaded, Policy::Hash] {
            let counts = router::route(policy, &sizes, *n as usize, 3);
            let total: usize = counts.iter().sum();
            if total != *n as usize {
                return Err(format!("{policy:?}: routed {total} != {n}"));
            }
            if counts.len() != sizes.len() {
                return Err(format!("{policy:?}: wrong width"));
            }
        }
        // LeastLoaded must never be worse balanced than Even.
        let ll = router::route(Policy::LeastLoaded, &sizes, *n as usize, 3);
        let ev = router::route(Policy::Even, &sizes, *n as usize, 3);
        let (bl, be) = (router::imbalance_after(&sizes, &ll), router::imbalance_after(&sizes, &ev));
        if bl > be + 1e-9 {
            return Err(format!("least-loaded imbalance {bl} > even {be}"));
        }
        Ok(())
    });
}

#[test]
fn prop_least_loaded_monotone_fill() {
    // Two invariants of the water-filling router:
    // 1. whenever `n` covers the total gap to the tallest block, the
    //    post-route spread is max−min ≤ 1 (the fill fully levels);
    // 2. a partial fill (n ≤ gap) never raises any block above the
    //    tallest original block (the level pass must not overshoot).
    let gen = PairGen(CountsVec { max_len: 48, max_val: 500 }, U64Range { lo: 0, hi: 2000 });
    check("least-loaded monotone fill", 0xF111, DEFAULT_CASES, &gen, |(sizes_raw, slack)| {
        if sizes_raw.is_empty() {
            return Ok(());
        }
        let sizes: Vec<u64> = sizes_raw.iter().map(|&s| s as u64).collect();
        let tallest = *sizes.iter().max().unwrap();
        let gap: u64 = sizes.iter().map(|&s| tallest - s).sum();
        let heights = |counts: &[usize]| -> Vec<u64> {
            sizes.iter().zip(counts).map(|(&s, &c)| s + c as u64).collect()
        };
        // Leveling fill: n ≥ gap.
        let n = gap + slack;
        let counts = router::route(Policy::LeastLoaded, &sizes, n as usize, 0);
        let after = heights(&counts);
        let mx = *after.iter().max().unwrap();
        let mn = *after.iter().min().unwrap();
        if mx - mn > 1 {
            return Err(format!("n={n} ≥ gap={gap} but spread {} > 1: {after:?}", mx - mn));
        }
        // Partial fill: n ≤ gap must stay under the tallest block.
        let n2 = gap.min(*slack);
        let counts2 = router::route(Policy::LeastLoaded, &sizes, n2 as usize, 0);
        let after2 = heights(&counts2);
        if let Some(&h) = after2.iter().find(|&&h| h > tallest) {
            return Err(format!("partial fill n={n2} ≤ gap={gap} overshot {h} > {tallest}"));
        }
        Ok(())
    });
}

#[test]
fn prop_prefix_index_locate_inverse() {
    let gen = CountsVec { max_len: 100, max_val: 300 };
    check("prefix index locate", 0x1DE, DEFAULT_CASES, &gen, |sizes_raw| {
        let sizes: Vec<u64> = sizes_raw.iter().map(|&s| s as u64).collect();
        let mut idx = PrefixIndex::new();
        idx.rebuild(sizes.iter().copied());
        let total: u64 = sizes.iter().sum();
        if idx.total() != total {
            return Err("total mismatch".into());
        }
        // Forward map must invert locate at every boundary ± 1.
        let mut probe = vec![0u64];
        let mut acc = 0;
        for &s in &sizes {
            acc += s;
            if acc > 0 {
                probe.push(acc - 1);
            }
            probe.push(acc);
        }
        for &i in &probe {
            match idx.locate(i) {
                Some((b, l)) => {
                    if i >= total {
                        return Err(format!("locate({i}) = Some but total {total}"));
                    }
                    if idx.start_of(b) + l != i {
                        return Err(format!("locate({i}) → ({b},{l}) doesn't invert"));
                    }
                    if l >= sizes[b] {
                        return Err(format!("local {l} ≥ size {}", sizes[b]));
                    }
                }
                None => {
                    if i < total {
                        return Err(format!("locate({i}) = None but total {total}"));
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_lfvector_capacity_bound_and_roundtrip() {
    let gen = CountsVec { max_len: 40, max_val: 200 };
    check("lfvector bounds", 0x1F5EC, 64, &gen, |chunks| {
        let spec = DeviceSpec::a100();
        let mut heap = VramHeap::with_capacity(spec, 1 << 26);
        let mut clock = Clock::new();
        let mut v: LfVector<u32> = LfVector::new(8);
        let mut shadow: Vec<u32> = Vec::new();
        for (i, &c) in chunks.iter().enumerate() {
            let vals: Vec<u32> = (0..c).map(|k| (i as u32) << 16 | k).collect();
            v.push_back_bulk(&vals, &mut heap, &mut clock).map_err(|e| e.to_string())?;
            shadow.extend_from_slice(&vals);
            let cap = v.capacity() as f64;
            let bound = 2.0 * v.len() as f64 + 2.0 * 8.0;
            if cap > bound {
                return Err(format!("cap {cap} > bound {bound} at len {}", v.len()));
            }
        }
        if v.len() != shadow.len() {
            return Err("length mismatch".into());
        }
        for (i, &want) in shadow.iter().enumerate() {
            if v.get(i) != Some(want) {
                return Err(format!("get({i}) = {:?} want {want}", v.get(i)));
            }
        }
        // Heap accounting matches.
        if heap.used() != v.allocated_bytes() {
            return Err("heap vs vector accounting".into());
        }
        Ok(())
    });
}

#[test]
fn prop_theory_capacity_bounds() {
    let gen = PairGen(U64Range { lo: 1, hi: 100_000_000 }, U64Range { lo: 1, hi: 2048 });
    check("ggarray_capacity bounds", 0x7E0, DEFAULT_CASES, &gen, |&(n, blocks)| {
        let fbs = 64;
        let cap = ggarray_capacity(n, blocks, fbs);
        if cap < n {
            return Err(format!("cap {cap} < n {n}"));
        }
        let bound = 2 * n + 2 * blocks * fbs;
        if cap > bound {
            return Err(format!("cap {cap} > 2n+2Bf = {bound} (n={n}, B={blocks})"));
        }
        Ok(())
    });
}

#[test]
fn prop_batcher_conserves_values() {
    use ggarray::coordinator::batcher::{BatchConfig, Batcher};
    let gen = CountsVec { max_len: 50, max_val: 300 };
    check("batcher conserves", 0xBA7C, DEFAULT_CASES, &gen, |pushes| {
        let mut b = Batcher::new(BatchConfig { max_values: 257, max_delay: std::time::Duration::from_secs(60) });
        let mut emitted = 0usize;
        let mut pushed = 0usize;
        for (i, &c) in pushes.iter().enumerate() {
            let vals = vec![i as f32; c as usize];
            pushed += vals.len();
            if let Some(batch) = b.push(&vals) {
                emitted += batch.values.len();
            }
        }
        if let Some(batch) = b.flush() {
            emitted += batch.values.len();
        }
        if emitted != pushed {
            return Err(format!("emitted {emitted} != pushed {pushed}"));
        }
        if b.pending_len() != 0 {
            return Err("pending after flush".into());
        }
        Ok(())
    });
}

#[test]
fn prop_vmm_accounting() {
    let gen = CountsVec { max_len: 30, max_val: 40 };
    check("vmm map/unmap accounting", 0x111, 64, &gen, |targets| {
        let spec = DeviceSpec::a100();
        let page = spec.cost.vmm_page_bytes;
        let mut pool = PhysicalPool::new(&spec);
        let mut clock = Clock::new();
        let mut range = VmmRange::reserve(&spec, 100 * page, &mut clock);
        let mut committed = 0u64;
        for &t in targets {
            let target = (t as u64 % 90) * page / 2;
            if target >= committed {
                range.grow_to(&spec, &mut pool, target, &mut clock).map_err(|e| e.to_string())?;
            } else {
                range.shrink_to(&spec, &mut pool, target, &mut clock).map_err(|e| e.to_string())?;
            }
            committed = target;
            if range.mapped_bytes() % page != 0 {
                return Err("mapped not page-granular".into());
            }
            if range.mapped_bytes() < committed {
                return Err("mapped < committed".into());
            }
            if range.mapped_bytes() - committed >= page {
                return Err(format!(
                    "slack {} ≥ one page after shrink/grow to {committed}",
                    range.mapped_bytes() - committed
                ));
            }
            if pool.used_bytes() != range.mapped_bytes() {
                return Err("pool vs range accounting".into());
            }
        }
        Ok(())
    });
}

/// `insert_bulk` must conserve every submitted value — nothing lost,
/// nothing duplicated, nothing reordered within a block — for all three
/// insertion algorithms (their semantics are identical; only the cost
/// model differs).
#[test]
fn prop_insert_bulk_conserves_values_all_kinds() {
    use ggarray::ggarray::array::{GgArray, GgConfig};
    use ggarray::insertion::InsertionKind;

    let gen = CountsVec { max_len: 12, max_val: 400 };
    check("insert_bulk conserves values", 0xC0115E7, 48, &gen, |chunks| {
        for kind in InsertionKind::ALL {
            let mut gg: GgArray<u32> = GgArray::new(
                GgConfig { num_blocks: 8, threads_per_block: 256, first_bucket_size: 8, insertion: kind },
                DeviceSpec::a100(),
            );
            let mut submitted: Vec<u32> = Vec::new();
            let mut counter = 0u32;
            for &c in chunks {
                let vals: Vec<u32> = (0..c).map(|k| counter + k).collect();
                counter += c;
                gg.insert_bulk(&vals, kind).map_err(|e| format!("{}: {e}", kind.name()))?;
                submitted.extend_from_slice(&vals);
            }
            if gg.len() != submitted.len() {
                return Err(format!("{}: len {} != submitted {}", kind.name(), gg.len(), submitted.len()));
            }
            if gg.len() > gg.capacity() {
                return Err(format!("{}: len {} > capacity {}", kind.name(), gg.len(), gg.capacity()));
            }
            let mut got = gg.to_vec();
            got.sort_unstable();
            let mut want = submitted.clone();
            want.sort_unstable();
            if got != want {
                return Err(format!("{}: multiset mismatch after {} chunks", kind.name(), chunks.len()));
            }
        }
        Ok(())
    });
}

/// `len() ≤ capacity()` must hold after ANY grow/shrink/clear sequence,
/// with the heap ledger agreeing with the structure's own accounting at
/// every step.
#[test]
fn prop_len_le_capacity_after_grow_shrink_clear() {
    use ggarray::ggarray::array::{GgArray, GgConfig};
    use ggarray::insertion::InsertionKind;

    let gen = CountsVec { max_len: 30, max_val: 900 };
    check("len ≤ capacity through grow/shrink/clear", 0x5C415E, 64, &gen, |ops| {
        let mut gg: GgArray<u32> = GgArray::new(
            GgConfig { num_blocks: 4, threads_per_block: 256, first_bucket_size: 4, insertion: InsertionKind::WarpScan },
            DeviceSpec::a100(),
        );
        for (step, &op) in ops.iter().enumerate() {
            match op % 4 {
                // grow + insert
                0 | 1 => {
                    let n = (op as usize / 2) % 700;
                    let split = gg.even_split(n);
                    gg.grow_for(&split).map_err(|e| e.to_string())?;
                    gg.insert_bulk(&vec![op; n], InsertionKind::WarpScan).map_err(|e| e.to_string())?;
                }
                // shrink to an arbitrary target (may exceed len: no-op)
                2 => {
                    gg.shrink_to(op as usize % 500);
                }
                // clear
                _ => {
                    gg.clear();
                    gg.rebuild_index_charged();
                }
            }
            if gg.len() > gg.capacity() {
                return Err(format!("step {step}: len {} > capacity {}", gg.len(), gg.capacity()));
            }
            if gg.heap().used() != gg.allocated_bytes() {
                return Err(format!(
                    "step {step}: heap {} != structure {}",
                    gg.heap().used(),
                    gg.allocated_bytes()
                ));
            }
        }
        Ok(())
    });
}

/// `get`/`set` must round-trip at random global indices, and reject
/// everything past the end.
#[test]
fn prop_get_set_roundtrip_random_indices() {
    use ggarray::ggarray::array::{GgArray, GgConfig};
    use ggarray::insertion::InsertionKind;

    let gen = PairGen(CountsVec { max_len: 8, max_val: 500 }, U64Range { lo: 0, hi: u64::MAX / 2 });
    check("get/set roundtrip", 0x6E75E7, 64, &gen, |(chunks, seed)| {
        let mut gg: GgArray<u32> = GgArray::new(
            GgConfig { num_blocks: 8, threads_per_block: 256, first_bucket_size: 8, insertion: InsertionKind::WarpScan },
            DeviceSpec::a100(),
        );
        for (i, &c) in chunks.iter().enumerate() {
            gg.insert_bulk(&vec![i as u32; c as usize], InsertionKind::WarpScan).map_err(|e| e.to_string())?;
        }
        let n = gg.len() as u64;
        let mut rng = Rng::new(*seed);
        for probe in 0..32 {
            if n == 0 {
                break;
            }
            let i = rng.below(n);
            let v = 0xBEEF_0000 ^ probe as u32 ^ (i as u32);
            if !gg.set(i, v) {
                return Err(format!("set({i}) rejected with len {n}"));
            }
            if gg.get(i) != Some(v) {
                return Err(format!("get({i}) = {:?}, want {v}", gg.get(i)));
            }
        }
        // Past-the-end accesses must fail cleanly.
        if gg.get(n).is_some() {
            return Err(format!("get({n}) succeeded past the end"));
        }
        for past in [n, n + 1, n + 1000] {
            if gg.get(past).is_some() {
                return Err(format!("get({past}) succeeded past the end"));
            }
        }
        Ok(())
    });
}

/// Shadow-model fuzz: a random op sequence (insert / rw_b / rw_g /
/// shrink / flatten) on the GGArray must agree with a plain Vec model at
/// every step. This is the strongest single correctness check on the
/// structure.
#[test]
fn prop_ggarray_matches_shadow_model() {
    use ggarray::ggarray::array::{GgArray, GgConfig};
    use ggarray::ggarray::flatten::flatten;
    use ggarray::insertion::InsertionKind;

    let mut rng = Rng::new(0x5AD0);
    for case in 0..24 {
        let blocks = 1usize << rng.range(0, 5); // 1..16
        let fbs = 1usize << rng.range(2, 7); // 4..64
        let mut gg: GgArray<u32> = GgArray::new(
            GgConfig { num_blocks: blocks, threads_per_block: 256, first_bucket_size: fbs, insertion: InsertionKind::WarpScan },
            DeviceSpec::a100(),
        );
        // Shadow: per-block Vecs (mirrors block-major semantics exactly).
        let mut shadow: Vec<Vec<u32>> = vec![Vec::new(); blocks];
        let mut counter = 0u32;
        for step in 0..60 {
            match rng.below(10) {
                0..=4 => {
                    // insert_bulk with even split
                    let n = rng.range(0, 500) as usize;
                    let vals: Vec<u32> = (0..n as u32).map(|i| counter + i).collect();
                    counter += n as u32;
                    gg.insert_bulk(&vals, InsertionKind::WarpScan).unwrap();
                    let counts: Vec<usize> =
                        (0..blocks).map(|i| n / blocks + usize::from(i < n % blocks)).collect();
                    let mut off = 0;
                    for (b, &c) in counts.iter().enumerate() {
                        shadow[b].extend_from_slice(&vals[off..off + c]);
                        off += c;
                    }
                }
                5 | 6 => {
                    gg.read_write_block(1.0, |x| *x = x.wrapping_mul(3).wrapping_add(1));
                    for v in shadow.iter_mut().flatten() {
                        *v = v.wrapping_mul(3).wrapping_add(1);
                    }
                }
                7 => {
                    gg.read_write_global(1.0, |x| *x = x.wrapping_add(7));
                    for v in shadow.iter_mut().flatten() {
                        *v = v.wrapping_add(7);
                    }
                }
                8 => {
                    let total: usize = shadow.iter().map(|s| s.len()).sum();
                    if total > 0 {
                        let keep = rng.below(total as u64 + 1) as usize;
                        gg.shrink_to(keep);
                        let split: Vec<usize> =
                            (0..blocks).map(|i| keep / blocks + usize::from(i < keep % blocks)).collect();
                        for (b, s) in shadow.iter_mut().enumerate() {
                            s.truncate(split[b].min(s.len()));
                        }
                    }
                }
                _ => {
                    let flat = flatten(&mut gg).unwrap();
                    let want: Vec<u32> = shadow.iter().flatten().copied().collect();
                    assert_eq!(flat.data, want, "case {case} step {step}: flatten mismatch");
                }
            }
            // Invariants after every step.
            let want: Vec<u32> = shadow.iter().flatten().copied().collect();
            assert_eq!(gg.len(), want.len(), "case {case} step {step}");
            // Spot-check a few random indices through the global index.
            for _ in 0..5 {
                if want.is_empty() {
                    break;
                }
                let i = rng.below(want.len() as u64);
                assert_eq!(gg.get(i), Some(want[i as usize]), "case {case} step {step} idx {i}");
            }
            assert_eq!(gg.get(want.len() as u64), None);
            if !want.is_empty() {
                let r = gg.overhead_ratio();
                let floor = (blocks * fbs) as f64 / want.len() as f64;
                assert!(r < 2.1 + 2.0 * floor, "case {case} step {step}: overhead {r} (floor {floor})");
            }
        }
    }
}

#[test]
fn prop_scan_artifacts_match_oracle_when_available() {
    if !ggarray::runtime::ArtifactManifest::available() {
        eprintln!("SKIP: artifacts missing");
        return;
    }
    let exec = ggarray::runtime::Executor::from_default_dir().unwrap();
    let mut rng = Rng::new(0x5CA9);
    for case in 0..24 {
        let n = rng.range(1, 1024) as usize;
        let counts: Vec<i32> = (0..n).map(|_| rng.below(16) as i32).collect();
        for fam in ["scan_warp_i32_", "scan_mxu_i32_"] {
            let (offsets, total) = exec.scan_offsets(fam, &counts).unwrap();
            let counts_u32: Vec<u32> = counts.iter().map(|&c| c as u32).collect();
            let (want, want_total) = assign_indices(0, &counts_u32);
            assert_eq!(total as u64, want_total, "{fam} case {case}");
            assert_eq!(offsets, want.iter().map(|&x| x as i64).collect::<Vec<_>>(), "{fam} case {case}");
        }
    }
}

#[test]
fn prop_heap_accounting_conserved_across_seal_compact_clear() {
    // The sealed store is epoch-owned VRAM now, so conservation is a
    // checkable ledger property: after EVERY op (insert / seal / flatten
    // / clear), the bytes resident in the shard heaps plus the epoch
    // heap must equal the allocated bytes Stats reports, sealed-store
    // residency must equal sealed_len × 4, and an op that FAILS (seal or
    // flatten OOM under the tight budget) must leave length, sealed
    // bytes and total heap usage byte-identically untouched — the
    // two-phase abort contract, exercised over random traces.
    let gen = CountsVec { max_len: 20, max_val: 5 };
    check("heap accounting conserved", 0x5EA1ED, 24, &gen, |ops| {
        for (budget, heap_capacity, epoch_heap) in [
            ("full-device", None, None),
            ("tight", Some(24 * 1024), Some(8 * 1024)),
        ] {
            let cfg = CoordinatorConfig {
                blocks: 8,
                shards: 2,
                first_bucket_size: 16,
                use_artifacts: false,
                compact_segments: 2,
                heap_capacity,
                epoch_heap,
                // Nothing flushes on its own: every flush happens at an
                // op barrier, keeping traces deterministic.
                batch: BatchConfig {
                    max_values: 1 << 20,
                    max_delay: std::time::Duration::from_secs(3600),
                },
                ..CoordinatorConfig::default()
            };
            let c = Coordinator::start(cfg);
            let mut counter = 0u64;
            for (i, &op) in ops.iter().enumerate() {
                let before = c.call(Request::Stats).expect_stats();
                let (what, failed) = match op % 5 {
                    0 | 1 => {
                        let n: usize = if op % 5 == 0 { 64 } else { 800 };
                        let values: Vec<f32> = (0..n)
                            .map(|k| ggarray::workload::synth_f32(counter + k as u64))
                            .collect();
                        counter += n as u64;
                        c.call(Request::Insert { values });
                        ("insert", false)
                    }
                    2 => match c.call(Request::Seal) {
                        Response::Sealed { .. } => ("seal", false),
                        Response::Error(_) => ("seal-oom", true),
                        other => return Err(format!("seal: {other:?}")),
                    },
                    3 => match c.call(Request::Flatten) {
                        Response::Flattened { .. } => ("flatten", false),
                        Response::Error(_) => ("flatten-oom", true),
                        other => return Err(format!("flatten: {other:?}")),
                    },
                    _ => {
                        c.call(Request::Clear);
                        ("clear", false)
                    }
                };
                let snap = c.call(Request::Stats).expect_stats();
                if snap.heap_used_bytes != snap.allocated_bytes {
                    return Err(format!(
                        "op {i} ({what}, {budget}): heap bytes {} != allocated {}",
                        snap.heap_used_bytes, snap.allocated_bytes
                    ));
                }
                if snap.sealed_bytes != snap.sealed_len * 4 {
                    return Err(format!(
                        "op {i} ({what}, {budget}): sealed bytes {} != sealed_len*4 {}",
                        snap.sealed_bytes,
                        snap.sealed_len * 4
                    ));
                }
                if failed
                    && (snap.len != before.len
                        || snap.sealed_bytes != before.sealed_bytes
                        || snap.sealed_segments != before.sealed_segments
                        || snap.heap_used_bytes != before.heap_used_bytes)
                {
                    return Err(format!(
                        "op {i} ({what}, {budget}): failed op tore state: \
                         len {}→{}, sealed {}→{} B ({}→{} segments), heap {}→{} B",
                        before.len,
                        snap.len,
                        before.sealed_bytes,
                        snap.sealed_bytes,
                        before.sealed_segments,
                        snap.sealed_segments,
                        before.heap_used_bytes,
                        snap.heap_used_bytes
                    ));
                }
            }
            // Clear must hand every byte back, in both budget regimes.
            c.call(Request::Clear);
            let last = c.call(Request::Stats).expect_stats();
            if last.heap_used_bytes != 0 || last.sealed_bytes != 0 {
                return Err(format!(
                    "{budget}: Clear leaked {} heap B / {} sealed B",
                    last.heap_used_bytes, last.sealed_bytes
                ));
            }
            c.shutdown();
        }
        Ok(())
    });
}

/// Executor-mode byte-identity: a random workload (insert / work / seal
/// / flatten / clear / query) replayed at 1/2/4 shards through the
/// serial worker (`executor_threads = 1`) and the work-stealing
/// scheduler (`executor_threads = 2` → two workers draining every
/// shard's chunks, whatever the shard count) must produce **identical
/// response payloads** — checksums, lengths, and the simulated
/// `sim_us`/`device_us` times exactly (per-shard clocks see the same
/// charge sequence in both modes; chunk results commit in deterministic
/// shard/range order regardless of steal order). Runs under a
/// full-device budget and a tight one, so the OOM paths (which the
/// scheduler pre-screens and routes down the serial fallback) are
/// byte-identical too. The serial side is itself pinned to the copying
/// reference by
/// [`prop_scratch_dispatch_byte_identical_to_copying_reference`], so
/// this transitively anchors the scheduler to the original pipeline.
#[test]
fn prop_executor_modes_byte_identical_across_shard_counts() {
    use ggarray::workload::synth_f32;

    let gen = PairGen(U64Range { lo: 1, hi: 48 }, CountsVec { max_len: 14, max_val: 700 });
    check("serial ≡ pooled executors (1/2/4 shards)", 0xEC5EC, 16, &gen, |(chunk, ops)| {
        let chunk = *chunk as usize;
        for (budget, heap_capacity, epoch_heap) in [
            ("full-device", None, None),
            ("tight", Some(24 * 1024), Some(8 * 1024)),
        ] {
            for shards in [1usize, 2, 4] {
                let start = |threads: usize| {
                    Coordinator::start(CoordinatorConfig {
                        blocks: 8,
                        shards,
                        first_bucket_size: 16,
                        use_artifacts: false,
                        compact_segments: 2,
                        heap_capacity,
                        epoch_heap,
                        executor_threads: threads,
                        batch: BatchConfig {
                            max_values: chunk,
                            max_delay: std::time::Duration::from_secs(3600),
                        },
                        ..CoordinatorConfig::default()
                    })
                };
                let serial = start(1);
                let pooled = start(2);
                let mut counter = 0u64;
                for (i, &op) in ops.iter().enumerate() {
                    let req = match op % 8 {
                        0 => Request::Seal,
                        1 => Request::Flatten,
                        2 => Request::Work { calls: 1 + (op as u32 % 2) },
                        3 => Request::Query { index: (i as u64).wrapping_mul(2654435761) % 2048 },
                        4 => Request::Clear,
                        _ => {
                            let values: Vec<f32> =
                                (0..op as u64).map(|k| synth_f32(counter + k)).collect();
                            counter += op as u64;
                            Request::Insert { values }
                        }
                    };
                    let a = serial.call(req.clone());
                    let b = pooled.call(req);
                    let (a, b) = (format!("{a:?}"), format!("{b:?}"));
                    if a != b {
                        return Err(format!(
                            "{budget}/{shards} shards, op {i}: serial {a} != pooled {b}"
                        ));
                    }
                }
                // Final seal + flatten barrier the tail, then the
                // observable state must agree field for field.
                for req in [Request::Seal, Request::Flatten] {
                    let a = format!("{:?}", serial.call(req.clone()));
                    let b = format!("{:?}", pooled.call(req));
                    if a != b {
                        return Err(format!("{budget}/{shards} shards, final: {a} != {b}"));
                    }
                }
                let sa = serial.call(Request::Stats).expect_stats();
                let sb = pooled.call(Request::Stats).expect_stats();
                let fields = |s: &ggarray::coordinator::metrics::MetricsSnapshot| {
                    (
                        (s.len, s.sealed_len, s.sealed_segments),
                        (s.sealed_bytes, s.heap_used_bytes, s.allocated_bytes),
                        (s.errors, s.seals, s.compactions, s.compaction_ooms, s.elements_inserted),
                        (s.sim_insert_ms, s.sim_work_ms, s.sim_flatten_ms),
                        (s.device_insert_ms, s.device_work_ms, s.device_flatten_ms),
                    )
                };
                if fields(&sa) != fields(&sb) {
                    return Err(format!(
                        "{budget}/{shards} shards: stats diverged\n serial {:?}\n pooled {:?}",
                        fields(&sa),
                        fields(&sb)
                    ));
                }
                // `executors` now reports the scheduler's worker count,
                // decoupled from the shard count.
                if sb.executors != 2 {
                    return Err(format!("scheduled run must report 2 workers, got {}", sb.executors));
                }
                serial.shutdown();
                pooled.shutdown();
            }
        }
        Ok(())
    });
}

// ------------------------------------------------------------------
// Byte-identity of the scratch-arena hot path (zero-copy dispatch +
// pooled flatten): for a random workload, every sealed layout and every
// response payload must match a host-side reference of the pre-refactor
// copying pipeline — a mirror batcher plus the collecting router applied
// per global block. The reference is shard-count-agnostic by
// construction, so the same oracle also proves 1/2/4-shard equivalence.
// ------------------------------------------------------------------

/// Pre-refactor reference: per-call batching (flush at `max_values`,
/// barrier before observers) and global per-block routing with the
/// collecting `router::route`, materialising every buffer the old path
/// materialised.
struct ReferenceStore {
    chunk: usize,
    routing: Policy,
    pending: Vec<f32>,
    blocks: Vec<Vec<f32>>,
    sealed: Vec<f32>,
    batch_seq: u64,
}

impl ReferenceStore {
    fn new(blocks: usize, chunk: usize, routing: Policy) -> ReferenceStore {
        ReferenceStore {
            chunk,
            routing,
            pending: Vec::new(),
            blocks: vec![Vec::new(); blocks],
            sealed: Vec::new(),
            batch_seq: 0,
        }
    }

    fn push(&mut self, values: &[f32]) {
        self.pending.extend_from_slice(values);
        if self.pending.len() >= self.chunk {
            self.flush();
        }
    }

    fn flush(&mut self) {
        if self.pending.is_empty() {
            return;
        }
        let values = std::mem::take(&mut self.pending);
        let sizes: Vec<u64> = self.blocks.iter().map(|b| b.len() as u64).collect();
        let counts = router::route(self.routing, &sizes, values.len(), self.batch_seq);
        self.batch_seq += 1;
        let mut off = 0usize;
        for (b, &c) in counts.iter().enumerate() {
            self.blocks[b].extend_from_slice(&values[off..off + c]);
            off += c;
        }
    }

    /// Seal: drain the live blocks (block-major order) behind the sealed
    /// prefix; returns this epoch's flat data.
    fn seal(&mut self) -> Vec<f32> {
        self.flush();
        let mut epoch = Vec::new();
        for b in &mut self.blocks {
            epoch.append(b);
        }
        self.sealed.extend_from_slice(&epoch);
        epoch
    }

    /// Full flatten: sealed prefix then the live epoch in block order.
    fn flat(&self) -> Vec<f32> {
        let mut all = self.sealed.clone();
        for b in &self.blocks {
            all.extend_from_slice(b);
        }
        all
    }

    fn total_len(&self) -> usize {
        self.sealed.len() + self.blocks.iter().map(|b| b.len()).sum::<usize>() + self.pending.len()
    }
}

#[test]
fn prop_scratch_dispatch_byte_identical_to_copying_reference() {
    use ggarray::coordinator::request::checksum;
    use ggarray::workload::synth_f32;

    let gen = PairGen(U64Range { lo: 1, hi: 48 }, CountsVec { max_len: 12, max_val: 600 });
    check("scratch-arena path ≡ copying reference (1/2/4 shards)", 0x5EA1, 32, &gen, |(chunk, ops)| {
        let chunk = *chunk as usize;
        for policy in [Policy::Even, Policy::LeastLoaded, Policy::Hash] {
            for shards in [1usize, 2, 4] {
                let cfg = CoordinatorConfig {
                    blocks: 8,
                    shards,
                    first_bucket_size: 16,
                    use_artifacts: false,
                    routing: policy,
                    batch: BatchConfig {
                        max_values: chunk,
                        max_delay: std::time::Duration::from_secs(3600),
                    },
                    ..CoordinatorConfig::default()
                };
                let c = Coordinator::start(cfg);
                let mut reference = ReferenceStore::new(8, chunk, policy);
                let mut counter = 0u64;
                let ctx = |i: usize| format!("{policy:?}/{shards} shards, op {i}");
                for (i, &op) in ops.iter().enumerate() {
                    match op % 7 {
                        0 => {
                            let expect = reference.seal();
                            match c.call(Request::Seal) {
                                Response::Sealed { epoch_len, sealed_len, checksum: sum, .. } => {
                                    if epoch_len != expect.len() as u64 {
                                        return Err(format!(
                                            "{}: epoch_len {epoch_len} != {}",
                                            ctx(i),
                                            expect.len()
                                        ));
                                    }
                                    if sum != checksum(&expect) {
                                        return Err(format!("{}: seal checksum diverged", ctx(i)));
                                    }
                                    if sealed_len != reference.sealed.len() as u64 {
                                        return Err(format!("{}: sealed_len diverged", ctx(i)));
                                    }
                                }
                                other => return Err(format!("{}: seal failed: {other:?}", ctx(i))),
                            }
                        }
                        1 => {
                            reference.flush(); // Flatten barriers pending inserts
                            let expect = reference.flat();
                            match c.call(Request::Flatten) {
                                Response::Flattened { len, checksum: sum, .. } => {
                                    if len != expect.len() as u64 || sum != checksum(&expect) {
                                        return Err(format!("{}: flatten diverged", ctx(i)));
                                    }
                                }
                                other => {
                                    return Err(format!("{}: flatten failed: {other:?}", ctx(i)))
                                }
                            }
                        }
                        2 => {
                            reference.flush(); // Query barriers pending inserts
                            let flat = reference.flat();
                            let idx = (i as u64).wrapping_mul(2654435761) % flat.len().max(1) as u64;
                            let got = c.call(Request::Query { index: idx }).expect_value();
                            let want = flat.get(idx as usize).copied();
                            if got != want {
                                return Err(format!(
                                    "{}: query({idx}) = {got:?} != {want:?}",
                                    ctx(i)
                                ));
                            }
                        }
                        _ => {
                            let values: Vec<f32> =
                                (0..op as u64).map(|k| synth_f32(counter + k)).collect();
                            counter += op as u64;
                            reference.push(&values);
                            match c.call(Request::Insert { values }) {
                                Response::Inserted { count, len, .. } => {
                                    if count != op as u64 {
                                        return Err(format!("{}: count diverged", ctx(i)));
                                    }
                                    if len != reference.total_len() as u64 {
                                        return Err(format!(
                                            "{}: len {len} != reference {}",
                                            ctx(i),
                                            reference.total_len()
                                        ));
                                    }
                                }
                                other => {
                                    return Err(format!("{}: insert failed: {other:?}", ctx(i)))
                                }
                            }
                        }
                    }
                }
                // Final barrier: one last seal + flatten must agree too
                // (covers workloads whose tail stayed pending).
                let expect = reference.seal();
                let (_, epoch_len, _, _, sum) = c.call(Request::Seal).expect_sealed();
                if epoch_len != expect.len() as u64 || sum != checksum(&expect) {
                    return Err(format!("{policy:?}/{shards}: final seal diverged"));
                }
                let full = reference.flat();
                match c.call(Request::Flatten) {
                    Response::Flattened { len, checksum: sum, .. } => {
                        if len != full.len() as u64 || sum != checksum(&full) {
                            return Err(format!("{policy:?}/{shards}: final flatten diverged"));
                        }
                    }
                    other => return Err(format!("final flatten failed: {other:?}")),
                }
                c.shutdown();
            }
        }
        Ok(())
    });
}

// ------------------------------------------------------------------
// Concurrency byte-identity of the admission frontend: N client threads
// racing real interleavings through bounded sessions must produce the
// exact same sealed layout, responses and stats ledger as one session
// replaying the same requests serially in the deterministic merge order
// (phase-major, client-id ascending, per-client FIFO) — the AtBarrier
// contract that makes the multi-client frontend safe to reason about.
// ------------------------------------------------------------------

/// Whether a trace position issues a query after its insert (a fixed
/// rule of the plan, so concurrent and serial runs query identically).
fn plan_queries(values_len: usize, sealed_before: u64) -> bool {
    values_len % 3 == 0 && sealed_before > 0
}

/// Deterministic query index for a trace position.
fn plan_query_index(phase: usize, client: usize, req: usize, sealed_before: u64) -> u64 {
    ((phase * 31 + client * 7 + req) as u64).wrapping_mul(2654435761) % sealed_before
}

/// Admit one request, retrying on (typed) shed. The test sizes the
/// admission window over the largest per-phase burst, so rejections
/// cannot actually occur here — the loop just keeps the call total.
fn admit(sess: &mut ggarray::coordinator::frontend::ClientSession, vals: &[f32]) {
    use ggarray::coordinator::request::Admission;
    let mut payload = vals.to_vec();
    loop {
        match sess.try_insert(payload) {
            Admission::Accepted { .. } => return,
            Admission::Rejected { values, .. } => {
                payload = values;
                std::thread::yield_now();
            }
            Admission::Closed { .. } => panic!("coordinator closed mid-trace"),
        }
    }
}

/// Drive one full run of a planned trace. `plan[p][c]` holds client
/// `c`'s requests for phase `p`; each phase ends with a seal issued
/// after every client quiesced. `concurrent` races one thread per
/// client inside each phase; serial replays the merge order through a
/// single session. Returns (per-seal responses, per-position query
/// responses in (phase, client, request) order, per-session accepted
/// ledgers, final stats).
fn run_planned_trace(
    cfg: CoordinatorConfig,
    plan: &[Vec<Vec<Vec<f32>>>],
    sealed_before: &[u64],
    concurrent: bool,
) -> (Vec<String>, Vec<String>, Vec<u64>, ggarray::coordinator::metrics::MetricsSnapshot) {
    let clients = plan[0].len();
    let c = Coordinator::start(cfg);
    let mut seals = Vec::new();
    let mut queries = Vec::new();
    let sessions = if concurrent {
        let mut sessions: Vec<_> = (0..clients).map(|_| c.session()).collect();
        for (p, phase) in plan.iter().enumerate() {
            let before = sealed_before[p];
            let phase_queries: Vec<Vec<String>> = std::thread::scope(|scope| {
                let handles: Vec<_> = sessions
                    .iter_mut()
                    .zip(phase)
                    .enumerate()
                    .map(|(cid, (sess, reqs))| {
                        scope.spawn(move || {
                            let mut qs = Vec::new();
                            for (r, vals) in reqs.iter().enumerate() {
                                admit(sess, vals);
                                if plan_queries(vals.len(), before) {
                                    let idx = plan_query_index(p, cid, r, before);
                                    let resp = sess.call(Request::Query { index: idx });
                                    qs.push(format!("{resp:?}"));
                                }
                            }
                            qs
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().expect("client thread panicked")).collect()
            });
            queries.extend(phase_queries.into_iter().flatten());
            seals.push(format!("{:?}", c.call(Request::Seal)));
        }
        sessions
    } else {
        let mut sess = c.session();
        for (p, phase) in plan.iter().enumerate() {
            let before = sealed_before[p];
            for (cid, reqs) in phase.iter().enumerate() {
                for (r, vals) in reqs.iter().enumerate() {
                    admit(&mut sess, vals);
                    if plan_queries(vals.len(), before) {
                        let idx = plan_query_index(p, cid, r, before);
                        queries.push(format!("{:?}", sess.call(Request::Query { index: idx })));
                    }
                }
            }
            seals.push(format!("{:?}", c.call(Request::Seal)));
        }
        vec![sess]
    };
    let ledgers: Vec<u64> = sessions.iter().map(|s| s.accepted_values()).collect();
    let stats = c.call(Request::Stats).expect_stats();
    c.shutdown();
    (seals, queries, ledgers, stats)
}

#[test]
fn prop_concurrent_clients_byte_identical() {
    use ggarray::coordinator::frontend::{FrontendConfig, MergePolicy};
    use ggarray::workload::synth_f32;

    const PHASES: usize = 2;

    let gen = PairGen(U64Range { lo: 1, hi: 64 }, CountsVec { max_len: 18, max_val: 120 });
    check("concurrent clients ≡ serial merge-order replay", 0xFACADE, 6, &gen, |(chunk, sizes)| {
        let chunk = *chunk as usize;
        for clients in [1usize, 4, 16] {
            // Distribute the request sizes round-robin over (phase,
            // client), then synthesise values in the deterministic merge
            // order so the data an element carries is a function of the
            // plan, not of admission timing.
            let mut shape = vec![vec![Vec::<usize>::new(); clients]; PHASES];
            for (r, &sz) in sizes.iter().enumerate() {
                shape[r % PHASES][(r / PHASES) % clients].push(sz as usize);
            }
            let mut counter = 0u64;
            let mut sealed_before = Vec::with_capacity(PHASES);
            let plan: Vec<Vec<Vec<Vec<f32>>>> = shape
                .iter()
                .map(|phase| {
                    sealed_before.push(counter);
                    phase
                        .iter()
                        .map(|reqs| {
                            reqs.iter()
                                .map(|&sz| {
                                    let vals: Vec<f32> =
                                        (0..sz as u64).map(|k| synth_f32(counter + k)).collect();
                                    counter += sz as u64;
                                    vals
                                })
                                .collect()
                        })
                        .collect()
                })
                .collect();
            let expected_ledger: Vec<u64> = (0..clients)
                .map(|cid| {
                    plan.iter()
                        .map(|phase| phase[cid].iter().map(|v| v.len() as u64).sum::<u64>())
                        .sum()
                })
                .collect();

            for shards in [1usize, 2, 4] {
                let cfg = |threads: usize| CoordinatorConfig {
                    blocks: 8,
                    shards,
                    first_bucket_size: 16,
                    use_artifacts: false,
                    compact_segments: 2,
                    executor_threads: threads,
                    batch: BatchConfig {
                        max_values: chunk,
                        max_delay: std::time::Duration::from_secs(3600),
                    },
                    // The admission window must cover a full per-client
                    // phase burst: AtBarrier only drains at sync points,
                    // so an under-provisioned window would shed forever
                    // mid-phase (documented frontend constraint).
                    frontend: FrontendConfig {
                        queue_requests: 64,
                        merge: MergePolicy::AtBarrier,
                        ..FrontendConfig::default()
                    },
                    ..CoordinatorConfig::default()
                };
                let fields = |s: &ggarray::coordinator::metrics::MetricsSnapshot| {
                    // Everything observable except `sessions` (clients vs
                    // 1 by construction) and wall-clock latency/uptime.
                    (
                        (s.len, s.sealed_len, s.sealed_segments, s.per_shard_len.clone()),
                        (s.sealed_bytes, s.heap_used_bytes, s.allocated_bytes),
                        (s.errors, s.seals, s.queries, s.inserts_requested, s.elements_inserted),
                        (s.admitted_requests, s.admitted_values, s.shed_requests, s.proposals),
                        (s.batches, s.flushes, s.coalesced_requests, s.compactions, s.compaction_ooms),
                        (s.sim_insert_ms, s.sim_work_ms, s.sim_flatten_ms),
                        (s.device_insert_ms, s.device_work_ms, s.device_flatten_ms),
                    )
                };
                let (g_seals, g_queries, g_ledgers, g_stats) =
                    run_planned_trace(cfg(1), &plan, &sealed_before, false);
                if g_ledgers != vec![expected_ledger.iter().sum::<u64>()] {
                    return Err(format!(
                        "{clients} clients/{shards} shards: serial replay accepted {g_ledgers:?}, \
                         plan holds {} values",
                        expected_ledger.iter().sum::<u64>()
                    ));
                }
                for threads in [1usize, 2] {
                    let ctx = format!("{clients} clients/{shards} shards/{threads} threads");
                    let (seals, queries, ledgers, stats) =
                        run_planned_trace(cfg(threads), &plan, &sealed_before, true);
                    if seals != g_seals {
                        return Err(format!(
                            "{ctx}: sealed epochs diverged from serial replay\n concurrent {seals:?}\n serial {g_seals:?}"
                        ));
                    }
                    if queries != g_queries {
                        return Err(format!("{ctx}: query responses diverged"));
                    }
                    if ledgers != expected_ledger {
                        return Err(format!(
                            "{ctx}: per-client accepted ledgers {ledgers:?} != plan {expected_ledger:?}"
                        ));
                    }
                    if stats.shed_requests != 0 {
                        return Err(format!("{ctx}: unexpected sheds ({})", stats.shed_requests));
                    }
                    if fields(&stats) != fields(&g_stats) {
                        return Err(format!(
                            "{ctx}: stats ledger diverged\n concurrent {:?}\n serial {:?}",
                            fields(&stats),
                            fields(&g_stats)
                        ));
                    }
                    if stats.sessions != clients as u64 {
                        return Err(format!(
                            "{ctx}: expected {clients} sessions, got {}",
                            stats.sessions
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}
