//! Integration: load the real AOT artifacts through PJRT and cross-check
//! the kernels against the Rust host oracles. Skips (with a notice) when
//! `make artifacts` hasn't been run.

use ggarray::insertion::assign_indices;
use ggarray::runtime::{ArtifactManifest, Executor};
use ggarray::util::rng::Rng;

fn executor_or_skip() -> Option<Executor> {
    if !ArtifactManifest::available() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return None;
    }
    Some(Executor::from_default_dir().expect("manifest present but unloadable"))
}

#[test]
fn scan_warp_matches_host_oracle() {
    let Some(exec) = executor_or_skip() else { return };
    let mut rng = Rng::new(7);
    for n in [1usize, 5, 128, 1000, 1024] {
        let counts: Vec<i32> = (0..n).map(|_| rng.below(4) as i32).collect();
        let out = exec.run_i32("scan_warp_i32_1024", &[&counts], n).unwrap();
        let incl = &out[0];
        let mut acc = 0i32;
        for (i, &c) in counts.iter().enumerate() {
            acc += c;
            assert_eq!(incl[i], acc, "n={n} i={i}");
        }
    }
}

#[test]
fn scan_mxu_agrees_with_scan_warp() {
    let Some(exec) = executor_or_skip() else { return };
    let mut rng = Rng::new(11);
    let counts: Vec<i32> = (0..1024).map(|_| rng.below(8) as i32).collect();
    let warp = exec.run_i32("scan_warp_i32_1024", &[&counts], 1024).unwrap();
    let mxu = exec.run_i32("scan_mxu_i32_1024", &[&counts], 1024).unwrap();
    assert_eq!(warp[0], mxu[0], "the two scan algorithms must agree exactly");
}

#[test]
fn scan_offsets_matches_assign_indices() {
    let Some(exec) = executor_or_skip() else { return };
    let counts_u32: Vec<u32> = vec![3, 0, 1, 7, 2, 0, 5];
    let counts_i32: Vec<i32> = counts_u32.iter().map(|&c| c as i32).collect();
    let (offsets, total) = exec.scan_offsets("scan_warp_i32_", &counts_i32).unwrap();
    let (want, want_total) = assign_indices(0, &counts_u32);
    assert_eq!(total as u64, want_total);
    assert_eq!(offsets, want.iter().map(|&x| x as i64).collect::<Vec<_>>());
}

#[test]
fn work_kernel_adds_thirty() {
    let Some(exec) = executor_or_skip() else { return };
    let xs: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5).collect();
    let out = exec.run_f32("work_f32_1024", &[&xs], xs.len()).unwrap();
    for (i, (&x, &y)) in xs.iter().zip(&out[0]).enumerate() {
        assert_eq!(y, x + 30.0, "i={i}");
    }
}

#[test]
fn padding_does_not_corrupt_scan() {
    // Inputs shorter than the artifact are zero-padded; zeros after the
    // real data must not change the inclusive prefix within range.
    let Some(exec) = executor_or_skip() else { return };
    let counts = vec![5i32; 10];
    let out = exec.run_i32("scan_warp_i32_1024", &[&counts], 10).unwrap();
    assert_eq!(out[0], (1..=10).map(|i| i * 5).collect::<Vec<i32>>());
}

#[test]
fn pick_size_picks_smallest_fitting() {
    let Some(exec) = executor_or_skip() else { return };
    assert_eq!(exec.pick_size("scan_warp_i32_", 100).unwrap(), "scan_warp_i32_1024");
    assert_eq!(exec.pick_size("scan_warp_i32_", 1024).unwrap(), "scan_warp_i32_1024");
    assert_eq!(exec.pick_size("scan_warp_i32_", 1025).unwrap(), "scan_warp_i32_4096");
    assert!(exec.pick_size("scan_warp_i32_", 100_000_000).is_err());
}

#[test]
fn oversized_input_rejected() {
    let Some(exec) = executor_or_skip() else { return };
    let too_big = vec![1i32; 5000];
    let err = exec.run_i32("scan_warp_i32_1024", &[&too_big], 5000).unwrap_err();
    assert!(err.to_string().contains("capacity"));
}

#[test]
fn insert_pack_artifact_full_pipeline() {
    // The fused L2 graph: mask + values → offsets + packed + total,
    // through one PJRT execution.
    use ggarray::runtime::{ArgValue, OutValue};
    let Some(exec) = executor_or_skip() else { return };
    if exec.manifest().get("insert_pack_f32_1024").is_none() {
        eprintln!("SKIP: insert_pack artifacts not built");
        return;
    }
    let mut rng = Rng::new(5);
    let n = 700usize;
    let mask: Vec<i32> = (0..n).map(|_| rng.below(2) as i32).collect();
    let values: Vec<f32> = (0..n).map(|i| i as f32 * 0.25).collect();
    let outs = exec
        .run_mixed("insert_pack_f32_1024", &[ArgValue::I32(&mask), ArgValue::F32(&values)])
        .unwrap();
    let offsets = outs[0].as_i32().unwrap();
    let packed = outs[1].as_f32().unwrap();
    let total = outs[2].as_i32().unwrap()[0] as usize;
    // Host oracle.
    let want: Vec<f32> = mask
        .iter()
        .zip(&values)
        .filter(|(&m, _)| m == 1)
        .map(|(_, &v)| v)
        .collect();
    assert_eq!(total, want.len());
    assert_eq!(&packed[..total], &want[..]);
    // Offsets where mask=1 are 0..total-1 in order.
    let got_off: Vec<i32> = mask
        .iter()
        .zip(offsets)
        .filter(|(&m, _)| m == 1)
        .map(|(_, &o)| o)
        .collect();
    assert_eq!(got_off, (0..total as i32).collect::<Vec<_>>());
    // Type mismatch is rejected cleanly.
    assert!(exec
        .run_mixed("insert_pack_f32_1024", &[ArgValue::F32(&values), ArgValue::F32(&values)])
        .is_err());
    let _ = OutValue::I32(vec![]); // exercise the enum export
}

#[test]
fn flatten_artifact_matches_host_flatten() {
    use ggarray::runtime::{ArgValue, OutValue};
    let Some(exec) = executor_or_skip() else { return };
    let Some(spec) = exec.manifest().get("flatten_f32_8192") else {
        eprintln!("SKIP: flatten artifacts not built");
        return;
    };
    let (blocks, cap) = (spec.inputs[0].shape[0], spec.inputs[0].shape[1]);
    let mut rng = Rng::new(9);
    // Bucketed input: block b holds sizes[b] live values.
    let sizes: Vec<i32> = (0..blocks).map(|_| rng.below(cap as u64 + 1) as i32).collect();
    let mut vals = vec![0f32; blocks * cap];
    let mut expect: Vec<f32> = Vec::new();
    for b in 0..blocks {
        for j in 0..sizes[b] as usize {
            let v = (b * 10_000 + j) as f32;
            vals[b * cap + j] = v;
            expect.push(v);
        }
    }
    let outs = exec
        .run_mixed("flatten_f32_8192", &[ArgValue::F32(&vals), ArgValue::I32(&sizes)])
        .unwrap();
    let flat = outs[0].as_f32().unwrap();
    let total = match &outs[1] {
        OutValue::I32(v) => v[0] as usize,
        _ => panic!("total should be i32"),
    };
    assert_eq!(total, expect.len());
    assert_eq!(&flat[..total], &expect[..]);
}

#[test]
fn warm_up_compiles_everything_once() {
    let Some(exec) = executor_or_skip() else { return };
    let n = exec.warm_up().unwrap();
    assert!(n >= 6, "expected ≥6 artifacts, got {n}");
    // Executions counter untouched by warm-up.
    assert_eq!(exec.executions(), 0);
    let _ = exec.run_i32("scan_warp_i32_1024", &[&vec![1i32; 4]], 4).unwrap();
    assert_eq!(exec.executions(), 1);
}
