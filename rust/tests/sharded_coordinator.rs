//! Sharded coordinator end-to-end: concurrency under mixed call/nowait
//! traffic, and the ISSUE acceptance criteria — a 4-shard `two_phase`
//! run produces byte-identical flattened contents to a 1-shard run, the
//! sealed-epoch path simulates cheaper per access than the unsealed
//! GGArray path, multi-shard runs beat single-shard on *critical-path*
//! simulated time (the parallel time model), and sealed-epoch compaction
//! bounds the segment count without touching a byte.

use std::time::Duration;

use ggarray::coordinator::batcher::BatchConfig;
use ggarray::coordinator::metrics::MetricsSnapshot;
use ggarray::coordinator::request::{Request, Response};
use ggarray::coordinator::service::{drive_workload, Coordinator, CoordinatorConfig, WorkloadRun};
use ggarray::workload::WorkloadSpec;

const CHUNK: usize = 4096;

fn cfg(blocks: usize, shards: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        blocks,
        shards,
        first_bucket_size: 32,
        use_artifacts: false,
        // Deterministic flushes: full chunks flush by size, tails at the
        // next barrier — never by wall-clock deadline.
        batch: BatchConfig { max_values: CHUNK, max_delay: Duration::from_secs(3600) },
        ..CoordinatorConfig::default()
    }
}

// ------------------------------------------------------------------
// Concurrency (satellite: threaded Client::call + insert_nowait, then
// shutdown drains and the totals match)
// ------------------------------------------------------------------

#[test]
fn concurrent_calls_and_nowait_inserts_conserve_elements() {
    let threads = 8usize;
    let rounds = 30usize;
    let call_chunk = 32usize;
    let nowait_chunk = 8usize;
    let coord = Coordinator::start(CoordinatorConfig {
        batch: BatchConfig { max_values: 256, max_delay: Duration::from_millis(1) },
        ..cfg(32, 4)
    });
    let mut handles = Vec::new();
    for t in 0..threads {
        let client = coord.client();
        handles.push(std::thread::spawn(move || {
            let mut sum = 0f64;
            for k in 0..rounds {
                // Synchronous insert…
                let base = (t * 1_000_000 + k * call_chunk) as f32;
                let values: Vec<f32> = (0..call_chunk).map(|i| base + i as f32).collect();
                sum += values.iter().map(|&v| v as f64).sum::<f64>();
                match client.call(Request::Insert { values }) {
                    Response::Inserted { count, .. } => assert_eq!(count, call_chunk as u64),
                    other => panic!("{other:?}"),
                }
                // …interleaved with fire-and-forget traffic.
                let nbase = (t * 1_000_000 + 500_000 + k * nowait_chunk) as f32;
                let nowait: Vec<f32> = (0..nowait_chunk).map(|i| nbase + i as f32).collect();
                sum += nowait.iter().map(|&v| v as f64).sum::<f64>();
                client.insert_nowait(nowait);
            }
            sum
        }));
    }
    let mut want_sum = 0f64;
    for h in handles {
        want_sum += h.join().unwrap();
    }
    let expect = (threads * rounds * (call_chunk + nowait_chunk)) as u64;
    // Stats barriers every pending batch itself (the same drain Shutdown
    // performs), making the totals observable before shutdown.
    let snap = match coord.call(Request::Stats) {
        Response::Stats(s) => s,
        other => panic!("{other:?}"),
    };
    assert_eq!(snap.elements_inserted, expect, "drained element count must match submitted");
    assert_eq!(snap.len, expect);
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.shards, 4);
    assert_eq!(snap.per_shard_len.iter().sum::<u64>(), expect);
    // Contents conserved, not just counted: sum over every element.
    let mut got_sum = 0f64;
    for i in 0..expect {
        got_sum += coord.call(Request::Query { index: i }).expect_value().unwrap() as f64;
    }
    assert_eq!(got_sum, want_sum);
    coord.shutdown();
}

#[test]
fn concurrent_traffic_across_a_seal_epoch_boundary() {
    // Threads keep inserting while the main thread seals: every element
    // must land either in the sealed prefix or the live epoch — none
    // dropped, none duplicated.
    let threads = 4usize;
    let rounds = 20usize;
    let chunk = 16usize;
    let coord = Coordinator::start(CoordinatorConfig {
        batch: BatchConfig { max_values: 64, max_delay: Duration::from_millis(1) },
        ..cfg(16, 4)
    });
    let mut handles = Vec::new();
    for t in 0..threads {
        let client = coord.client();
        handles.push(std::thread::spawn(move || {
            for k in 0..rounds {
                let base = (t * 100_000 + k * chunk) as f32;
                let values: Vec<f32> = (0..chunk).map(|i| base + i as f32).collect();
                client.call(Request::Insert { values });
            }
        }));
    }
    // Seal mid-traffic a few times.
    for _ in 0..3 {
        std::thread::sleep(Duration::from_millis(2));
        match coord.call(Request::Seal) {
            Response::Sealed { .. } => {}
            other => panic!("{other:?}"),
        }
    }
    for h in handles {
        h.join().unwrap();
    }
    let snap = match coord.call(Request::Stats) {
        Response::Stats(s) => s,
        other => panic!("{other:?}"),
    };
    let expect = (threads * rounds * chunk) as u64;
    assert_eq!(snap.elements_inserted, expect);
    assert_eq!(snap.len, expect);
    assert_eq!(snap.epoch, 3);
    assert_eq!(snap.seals, 3);
    assert_eq!(snap.sealed_len + snap.per_shard_len.iter().sum::<u64>(), expect);
    coord.shutdown();
}

// ------------------------------------------------------------------
// Acceptance criteria
// ------------------------------------------------------------------

fn run_workload(w: &WorkloadSpec, shards: usize) -> (WorkloadRun, u64) {
    let (run, checksum, _) = run_workload_cfg(w, cfg(32, shards));
    (run, checksum)
}

fn run_workload_cfg(w: &WorkloadSpec, cfg: CoordinatorConfig) -> (WorkloadRun, u64, MetricsSnapshot) {
    let c = Coordinator::start(cfg);
    let run = drive_workload(&c, w, CHUNK);
    let final_checksum = match c.call(Request::Flatten) {
        Response::Flattened { checksum, len, .. } => {
            assert_eq!(len, w.expected_final);
            checksum
        }
        other => panic!("{other:?}"),
    };
    let snap = c.call(Request::Stats).expect_stats();
    c.shutdown();
    (run, final_checksum, snap)
}

#[test]
fn four_shard_two_phase_byte_identical_to_one_shard() {
    let w = WorkloadSpec::two_phase_sharded(1 << 18, 1, 2, 3);
    let (run1, final1) = run_workload(&w, 1);
    let (run4, final4) = run_workload(&w, 4);
    assert_eq!(run1.seal_checksums.len(), 3);
    assert_eq!(
        run1.seal_checksums, run4.seal_checksums,
        "sealed epochs must be byte-identical across shard counts"
    );
    assert_eq!(final1, final4, "final flattened contents must be byte-identical");
    assert_eq!(run1.inserted, run4.inserted);
}

#[test]
fn sealed_epoch_work_cheaper_than_unsealed() {
    // Same element stream, same phases: the sealed run does its work
    // passes over flat (coalesced) epochs, the unsealed run over live
    // GGArray data (rw_b). The simulated per-access cost must favour the
    // sealed path — the paper's two-phase payoff, now service-level.
    let sealed_wl = WorkloadSpec::two_phase_sharded(1 << 18, 1, 2, 3);
    let unsealed_wl = WorkloadSpec::two_phase(1 << 18, 1, 2, 3);
    for shards in [1usize, 4] {
        let (sealed_run, _) = run_workload(&sealed_wl, shards);
        let (unsealed_run, _) = run_workload(&unsealed_wl, shards);
        assert!(
            sealed_run.work_sim_us < unsealed_run.work_sim_us,
            "{shards} shards: sealed work {} µs !< unsealed {} µs",
            sealed_run.work_sim_us,
            unsealed_run.work_sim_us
        );
    }
}

// ------------------------------------------------------------------
// Parallel time model (the corrected shard clock)
// ------------------------------------------------------------------

#[test]
fn insert_critical_path_monotone_in_shard_count() {
    // Property over the shard axis: the same even insert stream reports
    // S-shard critical-path sim time ≤ the 1-shard time for every S,
    // and strictly less for S ≥ 2 — the speedup the paper measures,
    // previously impossible because the ledger summed shard clocks.
    let w = WorkloadSpec::two_phase_sharded(1 << 18, 1, 0, 3);
    let sim_insert = |shards: usize| {
        let (_, _, snap) = run_workload_cfg(&w, cfg(32, shards));
        (snap.sim_insert_ms, snap.device_insert_ms)
    };
    let (sim1, dev1) = sim_insert(1);
    assert!((dev1 - sim1).abs() / sim1 < 1e-9, "1 shard: wall-model must equal device total");
    for shards in [2usize, 4, 8] {
        let (sim_s, dev_s) = sim_insert(shards);
        assert!(
            sim_s < sim1,
            "{shards}-shard insert critical path {sim_s} ms !< 1-shard {sim1} ms"
        );
        assert!(
            dev_s > sim_s,
            "{shards}-shard device total {dev_s} ms must exceed critical path {sim_s} ms"
        );
    }
    // More shards keep helping on this insert-heavy trace (allow a tiny
    // tolerance: per-shard fixed launch overheads grow with S).
    let (sim4, _) = sim_insert(4);
    let (sim2, _) = sim_insert(2);
    assert!(sim4 < sim2 * 1.05, "4-shard {sim4} ms should not regress past 2-shard {sim2} ms");
}

#[test]
fn work_skips_rw_b_on_empty_live_shards() {
    // After a seal the live shards are empty: a Work call should charge
    // only the sealed flat pass (plus the serial dispatch term), with no
    // per-shard rw_b launches. Compare against a store holding the same
    // data *live* (unsealed), where the rw_b path must dominate.
    let c = Coordinator::start(cfg(32, 4));
    // Large enough that memory traffic dominates launch/sync overheads.
    let n = 1usize << 20;
    c.call(Request::Insert { values: (0..n).map(|i| (i % 4096) as f32).collect() });
    let unsealed_us = match c.call(Request::Work { calls: 1 }) {
        Response::Worked { sim_us, .. } => sim_us,
        other => panic!("{other:?}"),
    };
    c.call(Request::Seal);
    let sealed_us = match c.call(Request::Work { calls: 1 }) {
        Response::Worked { sim_us, .. } => sim_us,
        other => panic!("{other:?}"),
    };
    assert!(
        sealed_us < unsealed_us / 2.0,
        "fully-sealed work {sealed_us} µs !≪ live work {unsealed_us} µs"
    );
    c.shutdown();
}

// ------------------------------------------------------------------
// Sealed-epoch compaction
// ------------------------------------------------------------------

#[test]
fn compaction_bounds_segments_and_preserves_bytes() {
    // Same seal-churn trace with compaction on (threshold 2) and off:
    // every per-epoch seal checksum and the final full-store flatten
    // must be byte-identical, while the compacting run keeps the sealed
    // segment count bounded by the threshold.
    let w = WorkloadSpec::seal_cycles(3_000, 8, 1);
    let threshold = 2usize;
    let (run_on, final_on, snap_on) =
        run_workload_cfg(&w, CoordinatorConfig { compact_segments: threshold, ..cfg(32, 4) });
    let (run_off, final_off, snap_off) =
        run_workload_cfg(&w, CoordinatorConfig { compact_segments: 0, ..cfg(32, 4) });
    assert_eq!(run_on.seal_checksums, run_off.seal_checksums, "per-epoch seals must not change");
    assert_eq!(final_on, final_off, "compaction must preserve the full sealed bytes");
    assert!(snap_on.compactions >= 3, "8 seals over threshold 2: {} compactions", snap_on.compactions);
    assert!(
        snap_on.sealed_segments <= threshold,
        "segments {} > threshold {threshold}",
        snap_on.sealed_segments
    );
    assert_eq!(snap_off.compactions, 0);
    assert_eq!(snap_off.sealed_segments, 8, "disabled run keeps one segment per epoch");
    assert_eq!(snap_on.sealed_len, snap_off.sealed_len);
    // The payoff: the sealed work pass launches one kernel per segment,
    // so the compacted store's work phase must simulate cheaper than the
    // 8-segment store's.
    assert!(
        run_on.work_sim_us < run_off.work_sim_us,
        "compacted work {} µs !< fragmented work {} µs",
        run_on.work_sim_us,
        run_off.work_sim_us
    );
}

#[test]
fn compaction_is_shard_count_invariant() {
    // Layout invariance survives compaction: 1-shard and 4-shard runs of
    // the same churn trace, both compacting aggressively, seal and
    // flatten to identical bytes.
    let w = WorkloadSpec::seal_cycles(2_000, 6, 0);
    let (run1, final1, _) =
        run_workload_cfg(&w, CoordinatorConfig { compact_segments: 1, ..cfg(32, 1) });
    let (run4, final4, snap4) =
        run_workload_cfg(&w, CoordinatorConfig { compact_segments: 1, ..cfg(32, 4) });
    assert_eq!(run1.seal_checksums, run4.seal_checksums);
    assert_eq!(final1, final4);
    assert_eq!(snap4.sealed_segments, 1, "threshold 1 compacts after every seal");
}

// ------------------------------------------------------------------
// Epoch-owned VRAM: the sealed store is a real memory transaction
// ------------------------------------------------------------------

#[test]
fn compaction_oom_aborts_but_preserves_bytes_and_service() {
    // seal_cycles churn under an epoch-heap budget that admits every
    // seal but can never hold the compaction gather's transient 2×:
    // every compaction attempt must OOM and abort byte-identically,
    // while the seals themselves keep committing and the final contents
    // stay byte-identical to a generously-budgeted run.
    let w = WorkloadSpec::seal_cycles(1_200, 4, 1);
    let per_epoch_bytes = 1_200u64 * 4; // 4800
    // Admission: 4 epochs × 4800 B = 19200 ≤ 24000. Compaction at seal 3
    // needs 14400 B transient on top of 14400 resident → always OOMs.
    let tight = CoordinatorConfig {
        heap_capacity: Some(5 * per_epoch_bytes + (1 << 20)),
        epoch_heap: Some(5 * per_epoch_bytes),
        compact_segments: 2,
        ..cfg(8, 2)
    };
    let generous = CoordinatorConfig { compact_segments: 2, ..cfg(8, 2) };
    let (run_tight, final_tight, snap_tight) = run_workload_cfg(&w, tight);
    let (run_gen, final_gen, snap_gen) = run_workload_cfg(&w, generous);
    // Byte-identity across wildly different compaction outcomes.
    assert_eq!(run_tight.seal_checksums, run_gen.seal_checksums);
    assert_eq!(final_tight, final_gen, "aborted compactions must never change sealed bytes");
    // The tight run surfaced the OOMs (response + metrics agree) and
    // kept every segment; the generous run merged them.
    assert_eq!(run_tight.compaction_ooms, 2, "seals 3 and 4 trigger a doomed gather");
    assert_eq!(snap_tight.compaction_ooms, 2);
    assert_eq!(snap_tight.compactions, 0);
    assert_eq!(snap_tight.sealed_segments, 4, "segments retained on abort");
    assert_eq!(snap_tight.sealed_len, 4_800);
    assert_eq!(snap_tight.sealed_bytes, 4 * per_epoch_bytes);
    assert_eq!(snap_tight.errors, 2, "compaction OOMs are the only errors");
    assert_eq!(run_gen.compaction_ooms, 0);
    assert!(snap_gen.compactions >= 1);
    assert!(snap_gen.sealed_segments <= 2);
}

#[test]
fn sealed_bytes_live_in_the_epoch_heap_across_the_lifecycle() {
    // Conservation through seal → compact → clear: at every barrier the
    // bytes in the shard heaps + epoch heap equal the allocated bytes
    // Stats reports, sealed bytes equal sealed_len × 4, and Clear
    // releases everything.
    let c = Coordinator::start(CoordinatorConfig { compact_segments: 2, ..cfg(8, 4) });
    let audit = |label: &str| -> MetricsSnapshot {
        let snap = c.call(Request::Stats).expect_stats();
        assert_eq!(
            snap.heap_used_bytes, snap.allocated_bytes,
            "{label}: every heap byte must be accounted to a live structure"
        );
        assert_eq!(snap.sealed_bytes, snap.sealed_len * 4, "{label}: sealed store residency");
        snap
    };
    for k in 0..5u32 {
        c.call(Request::Insert { values: vec![k as f32; 700] });
        audit("after insert");
        c.call(Request::Seal);
        let snap = audit("after seal");
        assert_eq!(snap.sealed_len, 700 * (k as u64 + 1));
    }
    let snap = audit("after churn");
    assert!(snap.compactions >= 1, "threshold 2 must have compacted");
    assert_eq!(snap.sealed_bytes, 5 * 700 * 4);
    c.call(Request::Clear);
    let snap = audit("after clear");
    assert_eq!(snap.heap_used_bytes, 0, "Clear must return every byte to the heaps");
    assert_eq!(snap.sealed_bytes, 0);
    c.shutdown();
}

// ------------------------------------------------------------------
// Insert OOM: dispatch stops at the first failed shard
// ------------------------------------------------------------------

#[test]
fn insert_oom_stops_dispatch_keeping_a_contiguous_prefix() {
    // Skewed pressure: 16 batches of 2 land on blocks 0,1 only (Even
    // routing puts the remainder on the first blocks), filling shard 0's
    // first buckets exactly while shards 1–3 stay empty. The follow-up
    // even batch then OOMs on shard 0's first block — and dispatch must
    // STOP there: with the old keep-going behaviour shards 1–3 would
    // still receive their slices, leaving a mid-stream hole.
    let cfg = CoordinatorConfig {
        blocks: 8,
        shards: 4,
        first_bucket_size: 16,
        use_artifacts: false,
        heap_capacity: Some(768),
        epoch_heap: Some(0),
        batch: BatchConfig { max_values: 2, max_delay: Duration::from_secs(3600) },
        ..CoordinatorConfig::default()
    };
    let c = Coordinator::start(cfg);
    let mut submitted: Vec<f32> = Vec::new();
    for k in 0..16 {
        let pair = vec![(2 * k) as f32, (2 * k + 1) as f32];
        submitted.extend(&pair);
        c.call(Request::Insert { values: pair });
    }
    // Phase-1 layout: block 0 = even-indexed values, block 1 = odd.
    let mut expect: Vec<f32> = submitted.iter().step_by(2).copied().collect();
    expect.extend(submitted.iter().skip(1).step_by(2));
    // Phase 2: 8 per block — shard 0 needs a second bucket (128 B) with
    // only 64 B free → OOM at its first block, nothing placed anywhere.
    c.call(Request::Insert { values: vec![500.0; 64] });
    let snap = c.call(Request::Stats).expect_stats();
    assert!(snap.errors >= 1, "the OOM must be reported");
    assert_eq!(
        snap.len, 32,
        "surviving data must be the phase-1 prefix — a hole means later shards were dispatched"
    );
    assert_eq!(snap.per_shard_len, vec![32, 0, 0, 0]);
    // Byte-level check via reads (the budget is too tight for a flatten
    // snapshot's temp destination — that is the point of the test).
    let got: Vec<f32> =
        (0..32).map(|i| c.call(Request::Query { index: i }).expect_value().unwrap()).collect();
    assert_eq!(got, expect, "surviving bytes must be exactly the pre-OOM contents");
    assert_eq!(c.call(Request::Query { index: 32 }).expect_value(), None);
    c.shutdown();
}

#[test]
fn insert_oom_byte_identical_across_shard_counts() {
    // Uniform pressure: 128 elements fill every first bucket exactly;
    // the per-shard budgets leave less than one second bucket free at
    // any shard count (576 total → 64 B free at 1 shard, 16 B per shard
    // at 4). The follow-up batch OOMs at block 0 in both configs, so the
    // surviving contents must be byte-identical — the shard-count
    // invariance the paper's layout argument promises, now under OOM.
    let run = |shards: usize| {
        let cfg = CoordinatorConfig {
            blocks: 8,
            shards,
            first_bucket_size: 16,
            use_artifacts: false,
            heap_capacity: Some(576),
            epoch_heap: Some(0),
            batch: BatchConfig { max_values: 128, max_delay: Duration::from_secs(3600) },
            ..CoordinatorConfig::default()
        };
        let c = Coordinator::start(cfg);
        c.call(Request::Insert { values: (0..128).map(|i| i as f32).collect() });
        c.call(Request::Insert { values: (0..128).map(|i| (1000 + i) as f32).collect() });
        let snap = c.call(Request::Stats).expect_stats();
        let contents: Vec<f32> = (0..snap.len)
            .map(|i| c.call(Request::Query { index: i }).expect_value().unwrap())
            .collect();
        c.shutdown();
        (snap.len, snap.errors, contents)
    };
    let (len1, errors1, contents1) = run(1);
    let (len4, errors4, contents4) = run(4);
    assert_eq!(len1, 128, "phase 1 fits exactly; phase 2 is fully rejected");
    assert_eq!(len4, len1, "OOM survivors must not depend on the shard count");
    assert_eq!(contents1, contents4, "surviving bytes must be shard-count invariant");
    assert!(errors1 >= 1 && errors4 >= 1);
}

#[test]
fn seal_checksum_matches_flatten_of_same_data() {
    // Sealing is just a retained flatten: for a single epoch the sealed
    // checksum must equal the Flatten checksum taken right before it.
    let c = Coordinator::start(cfg(32, 4));
    c.call(Request::Insert { values: (0..5000).map(|i| (i * 3) as f32).collect() });
    let flat_sum = match c.call(Request::Flatten) {
        Response::Flattened { checksum, .. } => checksum,
        other => panic!("{other:?}"),
    };
    let (epoch, epoch_len, sealed_len, _sim, seal_sum) = c.call(Request::Seal).expect_sealed();
    assert_eq!(epoch, 1);
    assert_eq!(epoch_len, 5000);
    assert_eq!(sealed_len, 5000);
    assert_eq!(seal_sum, flat_sum, "seal must capture exactly the flatten contents");
    // And the sealed data serves reads.
    assert_eq!(c.call(Request::Query { index: 0 }).expect_value(), Some(0.0));
    c.shutdown();
}
