//! Sharded coordinator end-to-end: concurrency under mixed call/nowait
//! traffic, and the ISSUE acceptance criteria — a 4-shard `two_phase`
//! run produces byte-identical flattened contents to a 1-shard run, and
//! the sealed-epoch path simulates cheaper per access than the unsealed
//! GGArray path.

use std::time::Duration;

use ggarray::coordinator::batcher::BatchConfig;
use ggarray::coordinator::request::{Request, Response};
use ggarray::coordinator::service::{drive_workload, Coordinator, CoordinatorConfig, WorkloadRun};
use ggarray::workload::WorkloadSpec;

const CHUNK: usize = 4096;

fn cfg(blocks: usize, shards: usize) -> CoordinatorConfig {
    CoordinatorConfig {
        blocks,
        shards,
        first_bucket_size: 32,
        use_artifacts: false,
        // Deterministic flushes: full chunks flush by size, tails at the
        // next barrier — never by wall-clock deadline.
        batch: BatchConfig { max_values: CHUNK, max_delay: Duration::from_secs(3600) },
        ..CoordinatorConfig::default()
    }
}

// ------------------------------------------------------------------
// Concurrency (satellite: threaded Client::call + insert_nowait, then
// shutdown drains and the totals match)
// ------------------------------------------------------------------

#[test]
fn concurrent_calls_and_nowait_inserts_conserve_elements() {
    let threads = 8usize;
    let rounds = 30usize;
    let call_chunk = 32usize;
    let nowait_chunk = 8usize;
    let coord = Coordinator::start(CoordinatorConfig {
        batch: BatchConfig { max_values: 256, max_delay: Duration::from_millis(1) },
        ..cfg(32, 4)
    });
    let mut handles = Vec::new();
    for t in 0..threads {
        let client = coord.client();
        handles.push(std::thread::spawn(move || {
            let mut sum = 0f64;
            for k in 0..rounds {
                // Synchronous insert…
                let base = (t * 1_000_000 + k * call_chunk) as f32;
                let values: Vec<f32> = (0..call_chunk).map(|i| base + i as f32).collect();
                sum += values.iter().map(|&v| v as f64).sum::<f64>();
                match client.call(Request::Insert { values }) {
                    Response::Inserted { count, .. } => assert_eq!(count, call_chunk as u64),
                    other => panic!("{other:?}"),
                }
                // …interleaved with fire-and-forget traffic.
                let nbase = (t * 1_000_000 + 500_000 + k * nowait_chunk) as f32;
                let nowait: Vec<f32> = (0..nowait_chunk).map(|i| nbase + i as f32).collect();
                sum += nowait.iter().map(|&v| v as f64).sum::<f64>();
                client.insert_nowait(nowait);
            }
            sum
        }));
    }
    let mut want_sum = 0f64;
    for h in handles {
        want_sum += h.join().unwrap();
    }
    let expect = (threads * rounds * (call_chunk + nowait_chunk)) as u64;
    // A Query barriers every pending batch (the same drain Shutdown
    // performs), making the totals observable before shutdown.
    let _ = coord.call(Request::Query { index: 0 });
    let snap = match coord.call(Request::Stats) {
        Response::Stats(s) => s,
        other => panic!("{other:?}"),
    };
    assert_eq!(snap.elements_inserted, expect, "drained element count must match submitted");
    assert_eq!(snap.len, expect);
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.shards, 4);
    assert_eq!(snap.per_shard_len.iter().sum::<u64>(), expect);
    // Contents conserved, not just counted: sum over every element.
    let mut got_sum = 0f64;
    for i in 0..expect {
        got_sum += coord.call(Request::Query { index: i }).expect_value().unwrap() as f64;
    }
    assert_eq!(got_sum, want_sum);
    coord.shutdown();
}

#[test]
fn concurrent_traffic_across_a_seal_epoch_boundary() {
    // Threads keep inserting while the main thread seals: every element
    // must land either in the sealed prefix or the live epoch — none
    // dropped, none duplicated.
    let threads = 4usize;
    let rounds = 20usize;
    let chunk = 16usize;
    let coord = Coordinator::start(CoordinatorConfig {
        batch: BatchConfig { max_values: 64, max_delay: Duration::from_millis(1) },
        ..cfg(16, 4)
    });
    let mut handles = Vec::new();
    for t in 0..threads {
        let client = coord.client();
        handles.push(std::thread::spawn(move || {
            for k in 0..rounds {
                let base = (t * 100_000 + k * chunk) as f32;
                let values: Vec<f32> = (0..chunk).map(|i| base + i as f32).collect();
                client.call(Request::Insert { values });
            }
        }));
    }
    // Seal mid-traffic a few times.
    for _ in 0..3 {
        std::thread::sleep(Duration::from_millis(2));
        match coord.call(Request::Seal) {
            Response::Sealed { .. } => {}
            other => panic!("{other:?}"),
        }
    }
    for h in handles {
        h.join().unwrap();
    }
    let _ = coord.call(Request::Query { index: 0 });
    let snap = match coord.call(Request::Stats) {
        Response::Stats(s) => s,
        other => panic!("{other:?}"),
    };
    let expect = (threads * rounds * chunk) as u64;
    assert_eq!(snap.elements_inserted, expect);
    assert_eq!(snap.len, expect);
    assert_eq!(snap.epoch, 3);
    assert_eq!(snap.seals, 3);
    assert_eq!(snap.sealed_len + snap.per_shard_len.iter().sum::<u64>(), expect);
    coord.shutdown();
}

// ------------------------------------------------------------------
// Acceptance criteria
// ------------------------------------------------------------------

fn run_workload(w: &WorkloadSpec, shards: usize) -> (WorkloadRun, u64) {
    let c = Coordinator::start(cfg(32, shards));
    let run = drive_workload(&c, w, CHUNK);
    let final_checksum = match c.call(Request::Flatten) {
        Response::Flattened { checksum, len, .. } => {
            assert_eq!(len, w.expected_final);
            checksum
        }
        other => panic!("{other:?}"),
    };
    c.shutdown();
    (run, final_checksum)
}

#[test]
fn four_shard_two_phase_byte_identical_to_one_shard() {
    let w = WorkloadSpec::two_phase_sharded(1 << 18, 1, 2, 3);
    let (run1, final1) = run_workload(&w, 1);
    let (run4, final4) = run_workload(&w, 4);
    assert_eq!(run1.seal_checksums.len(), 3);
    assert_eq!(
        run1.seal_checksums, run4.seal_checksums,
        "sealed epochs must be byte-identical across shard counts"
    );
    assert_eq!(final1, final4, "final flattened contents must be byte-identical");
    assert_eq!(run1.inserted, run4.inserted);
}

#[test]
fn sealed_epoch_work_cheaper_than_unsealed() {
    // Same element stream, same phases: the sealed run does its work
    // passes over flat (coalesced) epochs, the unsealed run over live
    // GGArray data (rw_b). The simulated per-access cost must favour the
    // sealed path — the paper's two-phase payoff, now service-level.
    let sealed_wl = WorkloadSpec::two_phase_sharded(1 << 18, 1, 2, 3);
    let unsealed_wl = WorkloadSpec::two_phase(1 << 18, 1, 2, 3);
    for shards in [1usize, 4] {
        let (sealed_run, _) = run_workload(&sealed_wl, shards);
        let (unsealed_run, _) = run_workload(&unsealed_wl, shards);
        assert!(
            sealed_run.work_sim_us < unsealed_run.work_sim_us,
            "{shards} shards: sealed work {} µs !< unsealed {} µs",
            sealed_run.work_sim_us,
            unsealed_run.work_sim_us
        );
    }
}

#[test]
fn seal_checksum_matches_flatten_of_same_data() {
    // Sealing is just a retained flatten: for a single epoch the sealed
    // checksum must equal the Flatten checksum taken right before it.
    let c = Coordinator::start(cfg(32, 4));
    c.call(Request::Insert { values: (0..5000).map(|i| (i * 3) as f32).collect() });
    let flat_sum = match c.call(Request::Flatten) {
        Response::Flattened { checksum, .. } => checksum,
        other => panic!("{other:?}"),
    };
    let (epoch, epoch_len, sealed_len, _sim, seal_sum) = c.call(Request::Seal).expect_sealed();
    assert_eq!(epoch, 1);
    assert_eq!(epoch_len, 5000);
    assert_eq!(sealed_len, 5000);
    assert_eq!(seal_sum, flat_sum, "seal must capture exactly the flatten contents");
    // And the sealed data serves reads.
    assert_eq!(c.call(Request::Query { index: 0 }).expect_value(), Some(0.0));
    c.shutdown();
}
