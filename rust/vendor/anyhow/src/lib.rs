//! Offline subset of the `anyhow` crate: a message-carrying [`Error`]
//! convertible from any `std::error::Error`, the [`Result`] alias, and the
//! `anyhow!` / `bail!` / `ensure!` macros. Exactly the surface this
//! workspace uses — no backtraces, no downcasting, no context chains.

use std::fmt;

/// A type-erased error holding a rendered message.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// `Error` deliberately does NOT implement `std::error::Error`; that is what
// makes this blanket conversion coherent (the same trick the real anyhow
// uses).
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result` defaulting its error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn macro_and_conversion_roundtrip() {
        fn io_fail() -> crate::Result<()> {
            std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(())
        }
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());

        fn guarded(n: u32) -> crate::Result<u32> {
            crate::ensure!(n < 10, "n too big: {n}");
            if n == 7 {
                crate::bail!("unlucky {n}");
            }
            Ok(n)
        }
        assert_eq!(guarded(3).unwrap(), 3);
        assert!(guarded(12).unwrap_err().to_string().contains("12"));
        assert!(guarded(7).unwrap_err().to_string().contains("unlucky"));
        let e = crate::anyhow!("x = {}", 5);
        assert_eq!(e.to_string(), "x = 5");
        assert_eq!(format!("{e:?}"), "x = 5");
    }
}
