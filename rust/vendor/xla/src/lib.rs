//! Null PJRT backend.
//!
//! This crate mirrors the slice of the `xla` (xla-rs / xla_extension)
//! API that the runtime layer uses, but carries no native XLA runtime:
//! creating the CPU client succeeds (so diagnostics report a platform),
//! while parsing or executing HLO returns a clear "runtime unavailable"
//! error. Every caller in this workspace already handles those errors by
//! falling back to host compute with identical numerics, so the full
//! system builds, tests, and runs offline; dropping the real `xla` crate
//! back in re-enables AOT execution without source changes.

use std::fmt;

/// Error type matching the real crate's `xla::Error` role.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

const UNAVAILABLE: &str = "PJRT runtime unavailable (null xla backend — install xla_extension and swap the real `xla` crate in to enable AOT execution)";

/// Supported element types for [`Literal`] construction/readback.
pub trait NativeType: Copy + fmt::Debug {}

impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u32 {}
impl NativeType for f32 {}
impl NativeType for f64 {}

/// Host-side tensor value. The null backend stores nothing beyond the
/// fact that one was requested; executing is impossible anyway.
#[derive(Debug, Clone)]
pub struct Literal {
    elements: usize,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal { elements: v.len() }
    }

    /// Reshape to `dims` (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.elements {
            return Err(Error(format!(
                "reshape: {} elements into shape {dims:?}",
                self.elements
            )));
        }
        Ok(self.clone())
    }

    /// Read back as a host vector — never reachable in the null backend
    /// (no executable can produce a result literal).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(Error(UNAVAILABLE.to_string()))
    }

    /// Unpack a tuple literal.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error(UNAVAILABLE.to_string()))
    }
}

/// Parsed `HloModuleProto` (text interchange format).
#[derive(Debug, Clone)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        if !std::path::Path::new(path).exists() {
            return Err(Error(format!("cannot read {path}: no such file")));
        }
        Err(Error(format!("cannot parse {path}: {UNAVAILABLE}")))
    }
}

/// An XLA computation wrapping a module proto.
#[derive(Debug, Clone)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// PJRT client handle.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    /// CPU client. Succeeds so platform diagnostics work; compilation is
    /// where the null backend reports itself.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error(UNAVAILABLE.to_string()))
    }
}

/// Compiled executable handle (never constructible in the null backend,
/// but the type must exist for caches and signatures).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error(UNAVAILABLE.to_string()))
    }
}

/// Device buffer returned by an execution.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error(UNAVAILABLE.to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_cpu_but_cannot_compile() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "cpu");
        assert!(c.compile(&XlaComputation).is_err());
    }

    #[test]
    fn missing_hlo_file_names_the_path() {
        let e = HloModuleProto::from_text_file("/no/such/file.hlo.txt").unwrap_err();
        assert!(e.to_string().contains("file.hlo.txt"));
    }

    #[test]
    fn literal_reshape_checks_element_count() {
        let l = Literal::vec1(&[1i32, 2, 3, 4]);
        assert!(l.reshape(&[2, 2]).is_ok());
        assert!(l.reshape(&[3]).is_err());
        assert!(l.to_vec::<i32>().is_err());
    }
}
